"""Sharding-rule resolution: strict vs waste-guard, fallthrough, dedup.

Uses a fake Mesh-like object so no jax devices are touched.
"""
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    RULES_DECODE,
    RULES_DECODE_LONG,
    RULES_TRAIN,
    Rules,
    spec_for_axes,
)


class FakeMesh:
    def __init__(self, shape: dict):
        self._shape = shape

    @property
    def shape(self):
        return self._shape

    @property
    def axis_names(self):
        return tuple(self._shape.keys())


MESH = FakeMesh({"data": 16, "model": 16})
MESH_POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_param_spec():
    spec = spec_for_axes(("vocab", "embed"), RULES_TRAIN, MESH, (32000, 4096))
    assert spec == P("model", "data")


def test_strict_refuses_uneven():
    spec = spec_for_axes(("stack", "embed", "heads", "head_dim"),
                         RULES_TRAIN, MESH, (32, 960, 15, 64))
    assert spec == P(None, "data")  # heads 15 % 16 != 0 -> replicated


def test_nonstrict_pads_mildly_uneven():
    spec = spec_for_axes(("batch", "seq", "act_heads", "head_dim"),
                         RULES_TRAIN, MESH, (256, 4096, 15, 64), strict=False)
    assert spec == P("data", None, "model")  # 15 on 16: 6.7% pad, allowed


def test_fallthrough_expert_dim():
    # mixtral: 8 experts on a 16-way axis -> ff picks up "model" instead
    spec = spec_for_axes(("experts", "embed", "mlp"), RULES_TRAIN, MESH,
                         (8, 4096, 14336), strict=False)
    assert spec == P(None, "data", "model")
    # phi3.5: 16 experts divide evenly -> EP on experts, ff replicated
    spec = spec_for_axes(("experts", "embed", "mlp"), RULES_TRAIN, MESH,
                         (16, 4096, 6400), strict=False)
    assert spec == P("model", "data")


def test_axis_used_once():
    # both dims want "model": second falls back
    r = Rules({"a": "model", "b": "model"})
    assert spec_for_axes(("a", "b"), r, MESH, (16, 16)) == P("model")


def test_missing_mesh_axes_dropped():
    spec = spec_for_axes(("batch", "seq"), RULES_TRAIN, MESH, (256, 4096))
    assert spec == P("data")  # ("pod","data") -> pod absent -> ("data",)
    spec = spec_for_axes(("batch", "seq"), RULES_TRAIN, MESH_POD, (256, 4096))
    assert spec == P(("pod", "data"))


def test_decode_rules_cache_seq():
    ax = ("stack", "batch", "cache_seq", "kv_heads", "head_dim")
    spec = spec_for_axes(ax, RULES_DECODE, MESH, (32, 128, 32768, 8, 128))
    assert spec == P(None, "data", "model")
    spec = spec_for_axes(ax, RULES_DECODE_LONG, MESH, (9, 1, 524288, 8, 128))
    assert spec == P(None, None, ("data", "model"))


def test_override_is_nondestructive():
    r2 = RULES_TRAIN.override(vocab=None)
    assert r2.get("vocab") is None
    assert RULES_TRAIN.get("vocab") == "model"
    assert r2.get("mlp") == "model"
