"""Continuous batching == standalone serving, request by request."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import Model
from repro.serve.scheduler import ContinuousBatcher



def _standalone(model, params, prompt, max_new, max_len):
    """Greedy continuation; returns (tokens, per-step logits)."""
    cache = model.init_cache(1, max_len)
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt[None])}, cache)
    toks = [int(jnp.argmax(logits[0]))]
    lgs = [np.asarray(logits[0], np.float32)]
    pos = len(prompt)
    while len(toks) < max_new:
        logits, cache = model.decode(
            params, jnp.asarray([toks[-1]], jnp.int32), cache,
            jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
        lgs.append(np.asarray(logits[0], np.float32))
        pos += 1
    return toks, lgs


def _assert_matches(got, want, lgs, ctx):
    """Sequences must match except across exact-logit ties (bf16 argmax
    tie-breaking differs between batched and standalone paths; after a tie
    the continuations legitimately diverge)."""
    for j, (g, w) in enumerate(zip(got, want)):
        if g == w:
            continue
        gap = abs(float(lgs[j][g]) - float(lgs[j][w]))
        # bf16 resolution at |logit|~3 is ~0.023; ties land within one ulp
        assert gap < 2.5e-2, (ctx, j, g, w, gap)
        return  # tie: stop comparing past the divergence
    assert len(got) == len(want), ctx


@pytest.mark.parametrize("arch", ["smollm-360m", "h2o-danube-3-4b",
                                  "falcon-mamba-7b", "mixtral-8x7b"])
def test_continuous_batching_matches_standalone(arch):
    cfg = smoke_config(arch)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    max_len = 96

    rng = np.random.default_rng(11)  # per-test: execution-order independent
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (8, 12, 5, 9, 7)]
    max_new = [6, 4, 5, 3, 6]

    batcher = ContinuousBatcher(model, params, batch_slots=2, max_len=max_len)
    for p, m in zip(prompts, max_new):
        batcher.submit(p, m)
    done = batcher.run()
    assert len(done) == len(prompts)

    for req, p, m in zip(done, prompts, max_new):
        want, lgs = _standalone(model, params, p, m, max_len)
        _assert_matches(req.out, want, lgs, req.rid)


def test_slots_are_reused():
    cfg = smoke_config("smollm-360m")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    batcher = ContinuousBatcher(model, params, batch_slots=1, max_len=64)
    for i in range(3):
        batcher.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 3)
    done = batcher.run()
    assert len(done) == 3
    assert all(len(r.out) == 3 for r in done)


# ------------------------------------------------ admission-control contract
# The serve/data server's tenant admission reuses this scheduler's
# slot-level pattern (decide under the lock, expensive work outside), so the
# pattern's own contract is pinned here: exhausted slots queue instead of
# overcommitting, the queue drains FIFO, and rids are stable under
# concurrent submission.

def _batcher(batch_slots):
    cfg = smoke_config("smollm-360m")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, ContinuousBatcher(
        model, params, batch_slots=batch_slots, max_len=64
    )


def test_admission_stops_at_slot_exhaustion():
    cfg, batcher = _batcher(2)
    rng = np.random.default_rng(7)
    for _ in range(5):
        batcher.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 4)
    batcher.step()
    # exactly B requests admitted; the rest wait in the queue, unstarted
    assert sum(r is not None for r in batcher.slots) == 2
    assert len(batcher.queue) == 3
    assert all(len(r.out) == 0 for r in batcher.queue)
    done = batcher.run()
    assert len(done) == 5  # queued requests were admitted later, not lost
    assert all(len(r.out) == 4 for r in done)


def test_admission_is_fifo():
    cfg, batcher = _batcher(1)
    rng = np.random.default_rng(9)
    # unequal max_new: only FIFO admission makes completion order == rid
    # order on a single slot (a LIFO/priority queue would reorder)
    for m in (5, 2, 4, 3):
        batcher.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32), m)
    done = batcher.run()
    assert [r.rid for r in done] == [0, 1, 2, 3]
    assert [r.rid for r in batcher.completed] == [0, 1, 2, 3]
    assert [len(r.out) for r in done] == [5, 2, 4, 3]


def test_rids_stable_under_concurrent_submission():
    import threading

    cfg, batcher = _batcher(2)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(40)]

    def submit(k):
        for p in prompts[k * 5:(k + 1) * 5]:
            batcher.submit(p, 2)

    threads = [threading.Thread(target=submit, args=(k,)) for k in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rids = [r.rid for r in batcher.queue]
    assert sorted(rids) == list(range(40))  # no collisions, no gaps


def test_rids_account_for_completed_requests():
    cfg, batcher = _batcher(1)
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    batcher.submit(prompt, 2)
    batcher.submit(prompt, 2)
    assert len(batcher.run()) == 2
    # auto-rids keep counting after completions drain the queue
    batcher.submit(prompt, 2)
    batcher.submit(prompt, 2, rid=99)  # explicit rid is preserved verbatim
    done = batcher.run()
    assert [r.rid for r in done] == [0, 1, 2, 99]
