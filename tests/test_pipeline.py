"""GPipe pipeline (shard_map + ppermute) vs sequential stage application.

Runs in a subprocess with fabricated host devices (the main process keeps
its single real device)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.pipeline import pipeline_apply

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    S, M, mb, d = 4, 6, 8, 16
    rng = np.random.default_rng(0)
    params = {{
        "w": jnp.asarray(rng.normal(0, 0.5, (S, d, d)), jnp.float32),
        "b": jnp.asarray(rng.normal(0, 0.1, (S, d)), jnp.float32),
    }}
    x = jnp.asarray(rng.normal(0, 1, (M, mb, d)), jnp.float32)

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    got = pipeline_apply(stage_fn, params, x, mesh=mesh, axis="model")

    # sequential reference
    ref = x
    for s in range(S):
        ps = {{"w": params["w"][s], "b": params["b"][s]}}
        ref = jax.vmap(lambda h: stage_fn(ps, h))(ref)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < 1e-5, err
    print("PIPELINE_OK", err)
""")


def test_pipeline_matches_sequential():
    script = _SCRIPT.format(src=os.path.abspath(SRC))
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PIPELINE_OK" in r.stdout
