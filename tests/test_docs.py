"""Docs-freshness contract (the CI gate in tools/check_docs.py, as tests).

Keeps the README honest from inside tier-1 as well: every registered
backend scheme has a row in the storage-backends table, and the quickstart
snippet actually executes against the current API.
"""
import importlib.util
import os

import pytest

_TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools", "check_docs.py")
_spec = importlib.util.spec_from_file_location("check_docs", _TOOLS)
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


@pytest.fixture(scope="module")
def readme_text():
    if not os.path.exists(check_docs.README):
        pytest.fail("README.md missing — the repo front door is gone")
    with open(check_docs.README) as f:
        return f.read()


def test_every_registered_scheme_documented(readme_text):
    missing = check_docs.check_scheme_table(readme_text)
    assert not missing, (
        f"schemes registered in code but absent from README.md: {missing}"
    )


def test_quickstart_snippet_executes(readme_text):
    snippet = check_docs.extract_quickstart(readme_text)
    # the snippet shows the real front-door API: the Pipeline chain + the
    # DataSpec JSON round-trip
    assert "Pipeline.from_uri" in snippet and "DataSpec.from_json" in snippet
    check_docs.run_quickstart(snippet)


def test_promised_docs_exist():
    root = os.path.join(os.path.dirname(__file__), "..")
    for rel in ("docs/adapters.md", "docs/architecture.md", "docs/pipeline.md"):
        p = os.path.join(root, rel)
        assert os.path.exists(p), f"{rel} promised by README/ROADMAP but missing"
        with open(p) as f:
            assert len(f.read()) > 1000, f"{rel} is a stub"


def test_every_dataspec_field_documented():
    with open(check_docs.PIPELINE_DOC) as f:
        text = f.read()
    undocumented = check_docs.check_spec_fields(text)
    assert not undocumented, (
        f"DataSpec fields missing from docs/pipeline.md: {undocumented} "
        "(regenerate with `python tools/check_docs.py --spec-table`)"
    )
