"""Multi-tenant batch serving: wire parity, resume refusal, shared-cache
fairness, admission/quota/backpressure — plus the IOStats merge/scoping and
segmented-cache satellites this subsystem is built on.

Every test runs under the runtime lock-order witness: the server adds a new
lock (and leans on IOStats/BlockCache locks from many threads), so any
acquisition order the static graph did not predict fails here.
"""
import json
import socket
import threading
import time

import numpy as np
import pytest

from repro.core.dataset import LoaderState
from repro.data import BlockCache, IOStats, SegmentedBlockCache
from repro.data.csr_store import CSRBatch
from repro.data.iostats import PendingIO
from repro.data.synth import generate_tahoe_like
from repro.pipeline import DataSpec, Pipeline
from repro.serve.data import (
    DataClient,
    DataServeServer,
    ProtocolError,
    ServeConfig,
    ServeError,
    decode_batch,
    encode_batch,
)


@pytest.fixture(autouse=True)
def _witness(lock_order_witness):
    yield


@pytest.fixture(scope="module")
def data_dir(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("serve_fixture"))
    generate_tahoe_like(d, n_cells=2000, n_genes=64, n_plates=3, seed=0)
    return d


def _spec(data_dir, *, seed=7, scheme="sharded-csr", **kw) -> DataSpec:
    pipe = (
        Pipeline.from_uri(f"{scheme}://{data_dir}")
        .strategy("block", block_size=16)
        .batch(32, fetch_factor=4)
        .seed(seed)
    )
    spec = pipe._spec
    return spec.replace(**kw) if kw else spec


@pytest.fixture()
def server():
    srv = DataServeServer(ServeConfig(max_tenants=3)).start()
    yield srv
    srv.stop()


def _batches_equal(a, b) -> bool:
    if isinstance(a, CSRBatch):
        return (
            isinstance(b, CSRBatch)
            and np.array_equal(a.data, b.data)
            and np.array_equal(a.indices, b.indices)
            and np.array_equal(a.indptr, b.indptr)
            and a.n_var == b.n_var
            and list(a.obs) == list(b.obs)
            and all(np.array_equal(a.obs[k], b.obs[k]) for k in a.obs)
        )
    return np.array_equal(a, b)


# ===================================================================== codec
def test_codec_csr_roundtrip_bitwise():
    rng = np.random.default_rng(0)
    batch = CSRBatch(
        data=rng.normal(size=300).astype(np.float32),
        indices=rng.integers(0, 64, 300).astype(np.int32),
        indptr=np.sort(rng.integers(0, 300, 31)).astype(np.int64),
        n_var=64,
        obs={"plate": np.array(["p1", "p2"] * 15), "y": np.arange(30)},
    )
    state = {"seed": 7, "epoch": 0, "fetch_cursor": 3, "batch_cursor": 1,
             "fingerprint": "abc"}
    out, st = decode_batch(encode_batch(batch, state))
    assert st == state
    assert _batches_equal(batch, out)


def test_codec_dense_and_map_roundtrip():
    x = np.random.default_rng(1).normal(size=(8, 5)).astype(np.float32)
    out, _ = decode_batch(encode_batch(x, {}))
    assert np.array_equal(x, out) and out.dtype == x.dtype
    m = {"tokens": np.arange(12, dtype=np.int32), "w": x}
    out2, _ = decode_batch(encode_batch(m, {}))
    assert list(out2) == ["tokens", "w"]
    assert all(np.array_equal(m[k], out2[k]) for k in m)


def test_codec_qint8_bounded_error_ints_exact():
    rng = np.random.default_rng(2)
    m = {"f": rng.normal(0, 3, 1000).astype(np.float32),
         "i": rng.integers(0, 9, 500).astype(np.int64)}
    payload = encode_batch(m, {}, compression="qint8")
    out, _ = decode_batch(payload)
    assert np.array_equal(m["i"], out["i"])  # ints never quantized
    step = np.abs(m["f"]).max() / 127.0
    assert np.abs(out["f"] - m["f"]).max() <= step  # per-block bound <= global
    # the fp32 array alone shrinks ~4x (4000 B -> 1024 codes + 16 scales);
    # the int array ships raw, so compare the saving, not a global ratio
    raw = len(encode_batch(m, {}))
    assert raw - len(payload) > 2500


def test_codec_rejects_unknown_batch_type():
    with pytest.raises(ProtocolError):
        encode_batch(object(), {})


# ==================================================================== config
def test_serve_config_validation_and_roundtrip():
    cfg = ServeConfig(max_tenants=2, quota_bytes=123, cache_policy="wtinylfu")
    assert ServeConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(ValueError):
        ServeConfig(max_tenants=0)
    with pytest.raises(ValueError):
        ServeConfig(compression="zstd")
    with pytest.raises(ValueError):
        ServeConfig(cache_policy="clock")
    with pytest.raises(ValueError):
        ServeConfig.from_dict({"max_tenant": 3})  # typo'd knob refused


# ==================================================== wire parity and resume
def test_wire_parity_bitwise_two_epochs(data_dir, server):
    spec = _spec(data_dir)
    local = Pipeline.from_spec(spec).build()
    with DataClient(server.address, spec) as cli:
        assert cli.fingerprint == spec.fingerprint()
        assert len(cli) == len(local)
        for _epoch in range(2):
            lit, rit = iter(local), iter(cli)
            for lb in lit:
                rb = next(rit)
                assert _batches_equal(lb, rb)
                # the post-batch resume state matches the local pipeline's
                assert cli.state() == local.state()
            with pytest.raises(StopIteration):
                next(rit)
            assert cli.state() == local.state()  # epoch advanced identically
    local.close()


def test_mid_epoch_resume_over_wire(data_dir, server):
    spec = _spec(data_dir)
    with DataClient(server.address, spec) as cli:
        it = iter(cli)
        for _ in range(5):
            next(it)
        ckpt = cli.state()
        assert ckpt.fingerprint == spec.fingerprint()

    local = Pipeline.from_spec(spec).build()
    local.load_state(ckpt)
    want = list(iter(local))
    local.close()

    with DataClient(server.address, spec) as cli2:
        cli2.load_state(ckpt)
        got = list(iter(cli2))
    assert len(got) == len(want) > 0
    assert all(_batches_equal(a, b) for a, b in zip(want, got))


def test_fingerprint_refusal_is_server_side(data_dir, server):
    spec = _spec(data_dir)
    with DataClient(server.address, spec) as cli:
        bad = cli.state().to_dict()
        bad["fingerprint"] = "deadbeefdeadbeef"
        # the CLIENT accepts the state unconditionally — the refusal must
        # come back over the wire, from the server's pipeline
        cli.load_state(bad)
        with pytest.raises(ValueError, match="fingerprint"):
            next(iter(cli))
        # the connection survives a refusal: a good state still streams
        cli.set_epoch(0)
        assert _batches_equal(
            next(iter(cli)),
            next(iter(Pipeline.from_spec(spec).build())),
        )


def test_abandoned_epoch_resyncs(data_dir, server):
    spec = _spec(data_dir)
    local = Pipeline.from_spec(spec).build()
    with DataClient(server.address, spec) as cli:
        for i, _b in enumerate(iter(cli)):
            if i == 2:
                break  # abandon mid-epoch: frames still in flight
        st = cli.state()
        local.load_state(st)
        want = list(iter(local))
        got = list(iter(cli))  # must resync, not misparse stale frames
    local.close()
    assert len(got) == len(want)
    assert all(_batches_equal(a, b) for a, b in zip(want, got))


def test_qint8_end_to_end_approximate(data_dir, server):
    spec = _spec(data_dir)
    local = Pipeline.from_spec(spec).build()
    with DataClient(server.address, spec, compression="qint8") as cli:
        assert cli.compression == "qint8"
        lb = next(iter(local))
        rb = next(iter(cli))
    local.close()
    # integer structure exact, float values within the quantizer bound
    assert np.array_equal(lb.indices, rb.indices)
    assert np.array_equal(lb.indptr, rb.indptr)
    assert lb.data.shape == rb.data.shape
    step = np.abs(lb.data).max() / 127.0
    assert np.abs(lb.data - rb.data).max() <= step + 1e-6


def test_bad_spec_refused(server):
    with pytest.raises(ServeError) as ei:
        DataClient(server.address, DataSpec(uri=None))  # in-process specs
    assert ei.value.code == "bad_spec"
    with pytest.raises(ServeError) as ei:
        DataClient(server.address, DataSpec(uri="sharded-csr:///nope"))
    assert ei.value.code == "bad_spec"


# ======================================================= shared-cache dedup
def test_two_tenants_share_one_cache(data_dir):
    """The whole point of the subsystem: tenant 2's reads are (mostly)
    tenant 1's cache hits — requests and bytes grow far less than 2x."""
    spec = _spec(data_dir).replace(
        uri=f"cloud://sharded-csr://{data_dir}?latency_scale=0"
    )
    srv = DataServeServer(ServeConfig(max_tenants=2)).start()
    try:
        with DataClient(srv.address, spec) as c1:
            n1 = sum(1 for _ in iter(c1))
        after_one = srv.stats().aggregate
        with DataClient(srv.address, spec) as c2:
            n2 = sum(1 for _ in iter(c2))
        after_two = srv.stats()
    finally:
        srv.stop()
    assert n1 == n2 > 0
    agg = after_two.aggregate
    assert after_one["requests"] > 0
    # tenant 2 re-read almost nothing: well under 2x on both axes
    assert agg["requests"] < 1.5 * after_one["requests"]
    assert agg["bytes_read"] < 1.5 * after_one["bytes_read"]
    assert agg["cache_hits"] > after_one["cache_hits"]
    # one pooled collection, and per-tenant attribution sums into the
    # aggregate (scoped children + shared base, no double counting)
    assert len(after_two.collections) == 1
    # rows are counted at fetch granularity, cache hit or not — each tenant's
    # epoch fetched exactly its delivered rows, and nothing double counts
    assert agg["rows"] == (n1 + n2) * 32


def test_per_tenant_attribution_scoped(data_dir):
    srv = DataServeServer(ServeConfig(max_tenants=2)).start()
    try:
        spec = _spec(data_dir)
        with DataClient(srv.address, spec) as cli:
            n = sum(1 for _ in iter(cli))
            st = cli.stats()
        tenants = st["tenants"]
        assert len(tenants) == 1
        t = tenants[0]
        assert n > 0
        assert t["iostats"]["rows"] == n * 32  # producer records -> child
        assert t["batches_sent"] == n and t["bytes_sent"] > 0
        assert st["shared"]["rows"] == 0  # nothing leaked onto the base
        assert st["aggregate"]["rows"] == n * 32  # merge() reassembles
    finally:
        srv.stop()


# ================================================ admission, quota, slots
def test_admission_fifo_under_slot_exhaustion(data_dir):
    """One slot, three tenants: B and C queue while A streams; the slot
    hands off in FIFO order when A leaves."""
    srv = DataServeServer(
        ServeConfig(max_tenants=1, admit_timeout_s=30.0)
    ).start()
    spec = _spec(data_dir)
    order: list = []
    olock = threading.Lock()

    def tenant(name, delay):
        time.sleep(delay)
        with DataClient(srv.address, spec) as c:
            with olock:
                order.append(name)
            next(iter(c))
    try:
        a = DataClient(srv.address, spec)  # holds the only slot
        next(iter(a))
        tb = threading.Thread(target=tenant, args=("B", 0.0))
        tc = threading.Thread(target=tenant, args=("C", 0.4))
        tb.start()
        tc.start()
        time.sleep(0.9)  # both queued behind A now
        adm = srv.stats().admission
        assert adm["active"] == 1 and adm["waiting"] == 2
        a.close()  # releases the slot -> FIFO handoff
        tb.join(timeout=20)
        tc.join(timeout=20)
    finally:
        srv.stop()
    assert order == ["B", "C"]


def test_admission_timeout_errors(data_dir):
    srv = DataServeServer(
        ServeConfig(max_tenants=1, admit_timeout_s=0.3)
    ).start()
    spec = _spec(data_dir)
    try:
        a = DataClient(srv.address, spec)
        next(iter(a))
        with pytest.raises(ServeError) as ei:
            DataClient(srv.address, spec)
        assert ei.value.code == "admission_timeout"
        a.close()
        adm = srv.stats().admission
        assert adm["admit_timeouts"] == 1
    finally:
        srv.stop()


def _crash(cli: DataClient) -> None:
    """Kill the client's socket mid-stream — no F_CLOSE, no goodbye."""
    sock = cli._sock
    cli._sock = None
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    sock.close()


def test_tenant_crash_releases_slot_to_queue_head(data_dir):
    """A tenant whose socket dies mid-stream releases its FIFO slot to the
    HEAD of the admission queue, exactly like a clean close."""
    srv = DataServeServer(
        ServeConfig(max_tenants=1, admit_timeout_s=30.0)
    ).start()
    spec = _spec(data_dir)
    order: list = []
    olock = threading.Lock()

    def tenant(name, delay):
        time.sleep(delay)
        with DataClient(srv.address, spec) as c:
            with olock:
                order.append(name)
            next(iter(c))
    try:
        a = DataClient(srv.address, spec)  # holds the only slot
        next(iter(a))
        tb = threading.Thread(target=tenant, args=("B", 0.0))
        tc = threading.Thread(target=tenant, args=("C", 0.4))
        tb.start()
        tc.start()
        time.sleep(0.9)
        assert srv.stats().admission["waiting"] == 2
        _crash(a)  # slot must hand off to B, then C
        tb.join(timeout=20)
        tc.join(timeout=20)
    finally:
        srv.stop()
    assert order == ["B", "C"]


def test_tenant_crash_50_cycles_no_leaks(data_dir):
    """50 crash/reconnect cycles over ONE streaming slot: every crash must
    release the slot (a single leak deadlocks admission), fold the departed
    tenant's IOStats into the aggregate, and drop the pooled collection's
    refcount — no leaked slots, tenants, or collection references."""
    srv = DataServeServer(
        ServeConfig(max_tenants=1, admit_timeout_s=10.0)
    ).start()
    spec = _spec(data_dir)
    cycles, per_cycle = 50, 2
    try:
        for _ in range(cycles):
            c = DataClient(srv.address, spec)
            it = iter(c)
            for _ in range(per_cycle):
                next(it)
            _crash(c)
        # the server notices a dead peer asynchronously: wait for the last
        # departure to settle before auditing for leaks
        deadline = time.time() + 10.0
        while time.time() < deadline:
            st = srv.stats()
            if st.admission["active"] == 0 and not st.tenants:
                break
            time.sleep(0.02)
        st = srv.stats()
        assert st.admission["active"] == 0
        assert st.admission["waiting"] == 0
        assert st.admission["admitted_total"] == cycles
        assert not st.tenants, "crashed tenants must not linger"
        # one pooled collection across all 50 tenants, zero refs at rest
        assert len(st.collections) == 1
        assert st.collections[0]["refs"] == 0
        # every departed tenant's counters folded into the aggregate: at
        # least the delivered rows (producers may have fetched ahead)
        batch_rows = spec.batch_size
        assert st.aggregate["rows"] >= cycles * per_cycle * batch_rows
        assert st.shared["rows"] == 0  # nothing leaked onto the shared base
    finally:
        srv.stop()


def test_quota_exhausted(data_dir):
    srv = DataServeServer(ServeConfig(quota_bytes=20_000)).start()
    spec = _spec(data_dir)
    try:
        with DataClient(srv.address, spec) as cli:
            with pytest.raises(ServeError) as ei:
                for _ in iter(cli):
                    pass
        assert ei.value.code == "quota_exhausted"
    finally:
        srv.stop()


def test_http_stats_endpoint(data_dir, server):
    spec = _spec(data_dir)
    with DataClient(server.address, spec) as cli:
        next(iter(cli))
    s = socket.create_connection(server.address)
    s.sendall(b"GET /stats HTTP/1.0\r\n\r\n")
    resp = b""
    while True:
        chunk = s.recv(1 << 16)
        if not chunk:
            break
        resp += chunk
    s.close()
    head, body = resp.split(b"\r\n\r\n", 1)
    assert b"200 OK" in head
    st = json.loads(body)
    assert set(st) >= {"tenants", "aggregate", "shared", "admission",
                       "collections", "config"}
    assert st["admission"]["admitted_total"] >= 1


# ===================================================== IOStats merge/scoping
def test_iostats_merge_adds_counters():
    a, b = IOStats(), IOStats()
    a.record(runs=1, rows=10, bytes_read=100, wall_s=0.5)
    b.record(runs=2, rows=20, bytes_read=200, wall_s=0.1, cache_hits=3)
    a.merge(b)
    assert a.runs == 3 and a.rows == 30 and a.bytes_read == 300
    assert a.cache_hits == 3 and b.runs == 2  # source untouched


def test_iostats_merge_min_semantics_for_entropy_floor():
    a, b, c = IOStats(), IOStats(), IOStats()
    a.record_diversity(3.0)
    b.record_diversity(1.5)
    a.merge(b)
    assert a.div_entropy_min == 1.5 and a.div_batches == 2
    a.merge(c)  # merging a diversity-free child must not clobber the min
    assert a.div_entropy_min == 1.5


def test_iostats_scoped_redirects_and_restores():
    base = IOStats()
    child = base.child()
    with base.scoped(child):
        base.record(runs=1, rows=5, bytes_read=50, wall_s=0.0)
        inner = base.child()
        with base.scoped(inner):  # reentrant: inner shadows outer
            base.record(runs=1, rows=1, bytes_read=1, wall_s=0.0)
    base.record(runs=1, rows=2, bytes_read=2, wall_s=0.0)
    assert (child.rows, inner.rows, base.rows) == (5, 1, 2)
    agg = base.child()
    for s in (base, child, inner):
        agg.merge(s)
    assert (agg.runs, agg.rows, agg.bytes_read) == (3, 8, 53)


def test_iostats_commit_follows_scope():
    base = IOStats()
    child = base.child()
    pend = PendingIO(runs=2, rows=7, bytes_read=70)
    with base.scoped(child):
        base.commit(pend)
    assert child.rows == 7 and base.rows == 0
    base.commit(PendingIO(runs=1, rows=3, bytes_read=30))
    assert base.rows == 3


def test_iostats_scoped_none_is_noop():
    base = IOStats()
    with base.scoped(None):
        base.record(runs=1, rows=4, bytes_read=4, wall_s=0.0)
    assert base.rows == 4


# ============================================= segmented cache (W-TinyLFU)
def _mixed_tenant_workload(cache):
    """Tenant A's hot redraw set vs tenant B's one-touch scan — the
    shared-cache fairness pathology.  Returns A's surviving hot blocks."""
    for k in range(10):
        cache.put(("A", k), b"x", 90)
    for _ in range(5):  # A redraws blocks 0..7: its hot set
        for k in range(8):
            cache.get(("A", k))
    # B scans 20 cold blocks exactly once; the sketch (aged) says the
    # scan candidates look marginally warmer than A's aged hot set
    est = lambda key: 2 if key[0] == "B" else 1  # noqa: E731
    for j in range(20):
        cache.put_admit(("B", j), b"y", 90, est)
    return [k for k in range(8) if cache.peek(("A", k)) is not None]


def test_segmented_cache_protects_hot_set_from_scan():
    plain = BlockCache(1000)
    seg = SegmentedBlockCache(1000)
    assert _mixed_tenant_workload(plain) == []  # LRU+TinyLFU: hot set gone
    assert _mixed_tenant_workload(seg) == list(range(8))  # protected survives
    snap = seg.snapshot()
    assert snap["rejections"] > 0  # scan victims lost their duels
    assert snap["protected_entries"] == 8
    assert set(snap) >= {"window_entries", "probation_entries",
                         "protected_bytes", "window_bytes"}


def test_segmented_cache_basic_lru_contract():
    seg = SegmentedBlockCache(1000)
    seg.put("a", 1, 400)
    seg.put("b", 2, 400)
    assert seg.get("a") == 1 and seg.get("b") == 2
    assert seg.get("missing") is None
    assert seg.hits == 2 and seg.misses == 1
    seg.discard("a")
    assert seg.peek("a") is None and len(seg) == 1
    seg.clear()
    assert len(seg) == 0 and seg.cur_bytes == 0


def test_wtinylfu_policy_through_pipeline_is_bit_identical(data_dir):
    batches = {}
    fps = {}
    for policy in ("lru", "wtinylfu"):
        pipe = (
            Pipeline.from_uri(f"sharded-csr://{data_dir}",
                              cache_bytes=1 << 20, cache_policy=policy)
            .strategy("block", block_size=16)
            .batch(32, fetch_factor=4)
            .seed(1)
            .build()
        )
        batches[policy] = [b.to_dense() for b in iter(pipe)]
        fps[policy] = pipe.spec.fingerprint()
        pipe.close()
    assert fps["lru"] == fps["wtinylfu"]  # the policy is content-free
    assert len(batches["lru"]) == len(batches["wtinylfu"]) > 0
    for x, y in zip(batches["lru"], batches["wtinylfu"]):
        assert np.array_equal(x, y)
