"""Property tests for EVERY exported `repro.core.theory` function (PR 8).

Six hundred generated cases (via `_hypothesis_compat`: real hypothesis when
installed, seeded deterministic sweeps when not) pin the §3.4 algebra:

- `entropy_bounds` is a true sandwich: 0 <= lower <= upper <= H(p), and it
  is exactly the clamp of `expected_entropy_f1` / `expected_entropy_large_f`;
- `expected_entropy_large_f` is monotone non-decreasing in m (Thm 3.1's
  bias term shrinks with batch size);
- `plugin_entropy` converges to `distribution_entropy` as counts scale
  (consistency of the plug-in estimator);
- `simulate_expected_entropy` (the Monte-Carlo ground truth) lands inside
  `entropy_bounds` for random (p, m, b, f);
- `batch_entropy` is bounded by log2 K, permutation/relabel-invariant, and
  `mean_batch_entropy` is exactly its per-batch mean/std.
"""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.theory import (
    batch_entropy,
    distribution_entropy,
    entropy_bounds,
    expected_entropy_f1,
    expected_entropy_large_f,
    mean_batch_entropy,
    plugin_entropy,
    simulate_expected_entropy,
    tahoe_plate_distribution,
)

_LN2 = np.log(2.0)


def _dirichlet(k: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).dirichlet(np.full(k, 5.0))


@given(
    k=st.integers(2, 14),
    m=st.integers(1, 2048),
    b=st.sampled_from([1, 2, 4, 8, 16, 64, 256]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=120, deadline=None)
def test_bounds_ordered_and_below_hp(k, m, b, seed):
    """0 <= lower <= upper <= H(p) for ANY (p, m, b) — including m < K,
    where the unclamped expansion goes negative on BOTH sides."""
    p = _dirichlet(k, seed)
    lo, hi = entropy_bounds(p, m, b)
    assert 0.0 <= lo <= hi + 1e-12, (lo, hi)
    assert hi <= distribution_entropy(p) + 1e-12


@given(
    k=st.integers(2, 14),
    m1=st.integers(1, 5000),
    m2=st.integers(1, 5000),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=100, deadline=None)
def test_large_f_monotone_in_m(k, m1, m2, seed):
    """Thm 3.1's E[H] never decreases as the batch grows."""
    p = _dirichlet(k, seed)
    lo_m, hi_m = sorted((m1, m2))
    assert (
        expected_entropy_large_f(p, lo_m)
        <= expected_entropy_large_f(p, hi_m) + 1e-12
    )


@given(k=st.integers(2, 14), seed=st.integers(0, 10_000))
@settings(max_examples=80, deadline=None)
def test_plugin_converges_to_distribution_entropy(k, seed):
    """The plug-in estimator is consistent: scaling exact counts up drives
    it to H(p), and a finer discretization never moves it further away
    (beyond the rounding floor)."""
    p = _dirichlet(k, seed)
    H = distribution_entropy(p)
    err_coarse = abs(plugin_entropy(np.round(p * 100)) - H)
    err_fine = abs(plugin_entropy(np.round(p * 1_000_000)) - H)
    assert err_fine < 0.02, (err_fine, H)
    assert err_fine <= err_coarse + 1e-6


@given(
    k=st.integers(2, 12),
    m=st.sampled_from([32, 64, 128]),
    b=st.sampled_from([1, 2, 4, 8, 16]),
    f=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=40, deadline=None)
def test_simulation_lands_inside_bounds(k, m, b, f, seed):
    """Monte-Carlo E[H] under the paper's sampling model respects the
    Corollary 3.3 sandwich, up to MC error + the O(B^-2) truncation."""
    p = _dirichlet(k, seed)
    trials = 150
    mean, std = simulate_expected_entropy(
        p, m, b, f, trials=trials, rng=np.random.default_rng(seed + 1)
    )
    lo, hi = entropy_bounds(p, m, b)
    slack = 3 * std / np.sqrt(trials) + 0.1
    assert lo - slack <= mean <= hi + slack, (lo, mean, hi, slack)


@given(
    k=st.integers(1, 20),
    n=st.integers(1, 512),
    shift=st.integers(0, 7),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=100, deadline=None)
def test_batch_entropy_bounded_and_invariant(k, n, shift, seed):
    """0 <= H(batch) <= log2 K; exact under permutation and label shift
    (zero-count classes contribute nothing); num_classes only pads."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, k, size=n)
    h = batch_entropy(labels)
    assert 0.0 <= h <= np.log2(max(1, k)) + 1e-9
    assert batch_entropy(rng.permutation(labels)) == h
    assert abs(batch_entropy(labels + shift) - h) < 1e-12
    assert abs(batch_entropy(labels, num_classes=k + 5) - h) < 1e-12


@given(
    k=st.integers(2, 10),
    n_batches=st.integers(1, 12),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=60, deadline=None)
def test_mean_batch_entropy_is_per_batch_mean(k, n_batches, seed):
    rng = np.random.default_rng(seed)
    batches = [
        rng.integers(0, k, size=int(rng.integers(1, 128)))
        for _ in range(n_batches)
    ]
    mean, std = mean_batch_entropy(batches)
    ents = np.array([batch_entropy(b) for b in batches])
    assert abs(mean - ents.mean()) < 1e-12
    assert abs(std - ents.std()) < 1e-12


@given(
    k=st.integers(2, 14),
    m=st.integers(1, 2048),
    b=st.sampled_from([1, 2, 4, 8, 16, 64]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=100, deadline=None)
def test_bounds_are_clamped_theorem_expansions(k, m, b, seed):
    """`entropy_bounds` IS (max(0, Thm 3.2), max(0, Thm 3.1)), and the f=1
    expansion never exceeds the large-f one (b >= 1)."""
    p = _dirichlet(k, seed)
    f1 = expected_entropy_f1(p, m, b)
    large = expected_entropy_large_f(p, m)
    assert f1 <= large + 1e-12
    lo, hi = entropy_bounds(p, m, b)
    assert abs(lo - max(0.0, f1)) < 1e-12
    assert abs(hi - max(0.0, large)) < 1e-12


def test_tahoe_plate_distribution_shape():
    """The reconstructed Tahoe plate vector hits the paper's two facts."""
    p = tahoe_plate_distribution()
    assert len(p) == 14
    assert abs(p.sum() - 1.0) < 1e-12
    assert 0.045 <= p.min() and p.max() <= 0.105
    assert abs(distribution_entropy(p) - 3.78) < 0.02
