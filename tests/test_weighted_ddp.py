"""Paper Appendix B headline: weighted/class-balanced sampling composes with
distributed round-robin (PyTorch's DistributedSampler x WeightedRandomSampler
exclusivity, resolved)."""
import numpy as np

from repro.core import BlockWeightedSampling, ClassBalancedSampling, ScDataset


def test_weighted_sampling_composes_with_ranks():
    n = 8192
    X = np.arange(n * 2, dtype=np.float32).reshape(n, 2)
    w = np.where(np.arange(n) < n // 2, 4.0, 1.0)
    strat = BlockWeightedSampling(block_size=8, weights=w)

    world = 4
    all_rows = []
    for r in range(world):
        ds = ScDataset(X, strat, batch_size=64, fetch_factor=2,
                       seed=7, rank=r, world_size=world)
        rows = np.concatenate([(b[:, 0] / 2).astype(int) for b in ds])
        all_rows.append(rows)
        # every rank individually sees the weighting
        frac = np.mean(rows < n // 2)
        assert 0.70 <= frac <= 0.90, (r, frac)

    # ranks partition the SAME weighted global sequence (no coordination)
    ds_ref = ScDataset(X, strat, batch_size=64, fetch_factor=2, seed=7)
    # union of rank streams == the global stream's prefix (up to fetch count)
    union = np.concatenate(all_rows)
    fetches = ds_ref._global_fetch_count()
    order = strat.epoch_indices(n, 7, 0)[: fetches * 128]
    assert sorted(union.tolist()) == sorted(order.tolist())


def test_class_balanced_with_ranks_rebalances_each_rank():
    n = 9000
    labels = np.repeat([0, 1, 2], [8000, 900, 100])
    X = np.stack([np.arange(n), labels], axis=1).astype(np.float32)
    strat = ClassBalancedSampling(block_size=1, labels=labels)
    for r in range(2):
        ds = ScDataset(X, strat, batch_size=64, fetch_factor=2,
                       seed=3, rank=r, world_size=2)
        ys = np.concatenate([b[:, 1].astype(int) for b in ds])
        frac = np.bincount(ys, minlength=3) / len(ys)
        assert frac.min() > 0.2, (r, frac)  # each rank near-balanced
