"""Theory (§3.4): bounds hold against Monte-Carlo simulation (hypothesis)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.theory import (
    batch_entropy,
    distribution_entropy,
    entropy_bounds,
    expected_entropy_f1,
    expected_entropy_large_f,
    plugin_entropy,
    simulate_expected_entropy,
    tahoe_plate_distribution,
)


def test_paper_eq5_numbers():
    """Paper Eq. (5): m=64, b=16 on the Tahoe plate distribution."""
    p = tahoe_plate_distribution()
    assert abs(distribution_entropy(p) - 3.78) < 0.02
    lo, hi = entropy_bounds(p, m=64, b=16)
    assert abs(lo - 1.43) < 0.05
    assert abs(hi - 3.63) < 0.05


def test_paper_section34_empirical_match():
    p = tahoe_plate_distribution()
    m1, s1 = simulate_expected_entropy(p, 64, 16, 1, trials=400,
                                       rng=np.random.default_rng(0))
    assert abs(m1 - 1.76) < 0.15  # paper: 1.76 +/- 0.33
    m256, s256 = simulate_expected_entropy(p, 64, 16, 256, trials=200,
                                           rng=np.random.default_rng(0))
    assert abs(m256 - 3.61) < 0.05  # paper: 3.61 +/- 0.08


@given(
    k=st.integers(2, 12),
    b=st.sampled_from([1, 2, 4, 8, 16]),
    f=st.sampled_from([1, 2, 8, 64]),
    seed=st.integers(0, 100),
)
@settings(max_examples=25, deadline=None)
def test_sandwich_bound_holds(k, b, f, seed):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.full(k, 5.0))
    m = 64
    mean, std = simulate_expected_entropy(p, m, b, f, trials=150, rng=rng)
    lo, hi = entropy_bounds(p, m, b)
    slack = 3 * std / np.sqrt(150) + 0.08  # MC error + O(B^-2) truncation
    assert lo - slack <= mean <= hi + slack, (lo, mean, hi)


@given(k=st.integers(2, 10), seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_monotone_in_f(k, seed):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.full(k, 5.0))
    m, b = 64, 16
    means = [simulate_expected_entropy(p, m, b, f, trials=200,
                                       rng=np.random.default_rng(seed))[0]
             for f in (1, 8, 64)]
    assert means[0] <= means[1] + 0.1
    assert means[1] <= means[2] + 0.1


def test_theorem_limits_consistency():
    p = tahoe_plate_distribution()
    lo, hi = entropy_bounds(p, 64, 16)
    assert abs(expected_entropy_f1(p, 64, 16) - lo) < 1e-9
    assert abs(expected_entropy_large_f(p, 64) - hi) < 1e-9


def test_plugin_entropy_edges():
    assert plugin_entropy(np.array([0, 0, 64])) == 0.0
    assert abs(plugin_entropy(np.array([32, 32])) - 1.0) < 1e-12
    assert plugin_entropy(np.zeros(4)) == 0.0
    assert batch_entropy(np.array([1, 1, 1, 1])) == 0.0


# ---- PR 8: pinned regressions for the audited edge cases.  Each of these
# crashed, returned a negative entropy, or inverted the sandwich before the
# fixes — they stay pinned so a refactor can't quietly reintroduce them.


def test_plugin_entropy_rejects_negative_counts():
    with pytest.raises(ValueError, match="non-negative"):
        plugin_entropy(np.array([3, -1, 2]))


def test_batch_entropy_empty_batch_is_zero():
    # np.bincount rejects the default-float64 empty array; the empty batch
    # must short-circuit to 0.0 instead of raising.
    assert batch_entropy(np.array([])) == 0.0
    assert batch_entropy(np.array([]), num_classes=14) == 0.0


def test_batch_entropy_accepts_integer_valued_floats():
    # labels arriving as float64 (e.g. straight out of an obs column) are
    # cast, not rejected
    assert abs(batch_entropy(np.array([0.0, 1.0, 0.0, 1.0])) - 1.0) < 1e-12


def test_single_class_batch_is_exactly_positive_zero():
    # -(1 * log2(1)) is -0.0 in IEEE; counters and JSON must see +0.0
    h = batch_entropy(np.array([7, 7, 7]))
    assert h == 0.0 and not np.signbit(h)


def test_entropy_bounds_clamps_both_sides_when_m_below_k():
    # m < K: BOTH expansion terms go negative; clamping only the lower
    # bound used to invert the sandwich (lo=0 > hi<0)
    p = np.full(32, 1 / 32)
    lo, hi = entropy_bounds(p, m=4, b=4)
    assert 0.0 <= lo <= hi


def test_simulate_handles_non_dividing_block_size():
    # m=10, b=3: floor division left a 9-cell buffer and the m-cell
    # without-replacement draw raised; B must round UP
    mean, std = simulate_expected_entropy(
        np.full(4, 0.25), m=10, b=3, f=1,
        trials=20, rng=np.random.default_rng(0),
    )
    assert 0.0 <= mean <= 2.0


def test_theory_validates_nonpositive_arguments():
    p = np.array([0.5, 0.5])
    with pytest.raises(ValueError):
        expected_entropy_large_f(p, 0)
    with pytest.raises(ValueError):
        expected_entropy_f1(p, 64, 0)
    with pytest.raises(ValueError):
        entropy_bounds(p, -1, 4)
    with pytest.raises(ValueError):
        simulate_expected_entropy(p, 64, 16, 0)
    with pytest.raises(ValueError):
        simulate_expected_entropy(p, 64, 16, 1, trials=0)
