"""Per-architecture smoke tests: reduced config, one fwd/train step on CPU,
shape + finiteness assertions, prefill/decode round trip (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import Model, active_param_count, param_count
from repro.train.optimizer import AdamWConfig
from repro.train.step import make_train_state, make_train_step

RNG = np.random.default_rng(0)

# published sizes (±12% tolerance: embeddings/norm bookkeeping differs)
_EXPECT_B = {
    "internvl2-26b": 20.0,  # LLM backbone of the 26B (ViT ~6B is stubbed)
    "jamba-1.5-large-398b": 398.0,
    "falcon-mamba-7b": 7.3,
    "mixtral-8x7b": 46.7,
    "phi3.5-moe-42b-a6.6b": 42.0,
    "gemma-7b": 8.5,
    "phi3-medium-14b": 14.0,
    "smollm-360m": 0.36,
    "h2o-danube-3-4b": 4.0,
    "whisper-large-v3": 1.55,
}
_EXPECT_ACTIVE_B = {
    "jamba-1.5-large-398b": 94.0,
    "mixtral-8x7b": 12.9,
    "phi3.5-moe-42b-a6.6b": 6.6,
}


def _batch(cfg, B=2, S=32):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    batch["labels"] = batch["tokens"]
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (B, cfg.num_patches, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            RNG.normal(0, 1, (B, S, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    cfg = get_config(arch)
    cfg.validate()
    n = param_count(cfg) / 1e9
    assert abs(n - _EXPECT_B[arch]) / _EXPECT_B[arch] < 0.12, (arch, n)
    if arch in _EXPECT_ACTIVE_B:
        na = active_param_count(cfg) / 1e9
        assert abs(na - _EXPECT_ACTIVE_B[arch]) / _EXPECT_ACTIVE_B[arch] < 0.12


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = Model(cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    params, _ = model.init(jax.random.PRNGKey(0))
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    state = make_train_state(model, jax.random.PRNGKey(1), AdamWConfig(lr=1e-3))
    state, metrics = step(state, batch)
    assert int(state["step"]) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = smoke_config(arch)
    model = Model(cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    params, _ = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, max_len=S + 4)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(2):
        logits, cache = model.decode(params, tok, cache, jnp.asarray(S + i, jnp.int32))
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_decode_consistent_with_forward():
    """Greedy decode logits == teacher-forced forward logits (causal LM)."""
    cfg = smoke_config("h2o-danube-3-4b")  # dense + SWA exercises ring cache
    model = Model(cfg)
    B, S = 1, 12
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    params, _ = model.init(jax.random.PRNGKey(0))
    full_logits, _ = model.forward(params, {"tokens": tokens, "labels": tokens})

    cache = model.init_cache(B, max_len=S)
    lg, cache = model.prefill(params, {"tokens": tokens[:, :4]}, cache)
    np.testing.assert_allclose(
        np.asarray(lg, np.float32), np.asarray(full_logits[:, 3], np.float32),
        atol=2e-2, rtol=2e-2,
    )
    # feed gold tokens one by one; decode logits must track forward logits
    for pos in range(4, S):
        lg, cache = model.decode(params, tokens[:, pos], cache,
                                 jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg, np.float32), np.asarray(full_logits[:, pos], np.float32),
            atol=2e-2, rtol=2e-2,
        )


def test_ssm_prefill_decode_consistency():
    """SSM state threading: prefill(S) + decode == forward(S+1)."""
    cfg = smoke_config("falcon-mamba-7b")
    model = Model(cfg)
    B, S = 1, 10
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    params, _ = model.init(jax.random.PRNGKey(0))
    full_logits, _ = model.forward(params, {"tokens": tokens, "labels": tokens})
    cache = model.init_cache(B, max_len=S)
    lg, cache = model.prefill(params, {"tokens": tokens[:, :S - 1]}, cache)
    np.testing.assert_allclose(
        np.asarray(lg, np.float32), np.asarray(full_logits[:, S - 2], np.float32),
        atol=2e-2, rtol=2e-2,
    )
    lg, cache = model.decode(params, tokens[:, S - 1], cache,
                             jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(lg, np.float32), np.asarray(full_logits[:, S - 1], np.float32),
        atol=2e-2, rtol=2e-2,
    )
