"""Unified backend layer: registry, cross-shard planning, LRU cache, resume.

Covers the Collection protocol + read planner + block cache substrate
(repro.data.backend / repro.data.readplan) over all four storage formats.
"""
import numpy as np
import pytest

from repro.core import BlockShuffling, BlockWeightedSampling, PrefetchPool, ScDataset
from repro.data import (
    IOStats,
    TokenStore,
    generate_token_corpus,
    open_collection,
    registered_schemes,
    write_chunked_store,
    write_csr_shard,
)
from repro.data.readplan import (
    BlockCache,
    coalesce_rows,
    plan_reads,
    split_at_boundaries,
    split_max_extent,
)


def _write_csr(rng, path, n, g):
    """One canonical CSR shard on disk + its dense reference."""
    lens = rng.integers(1, 6, n)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(lens, out=indptr[1:])
    total = int(indptr[-1])
    data = rng.normal(size=total).astype(np.float32)
    indices = np.empty(total, np.int32)
    for i in range(n):
        k = int(lens[i])
        indices[indptr[i]:indptr[i + 1]] = np.sort(
            rng.choice(g, size=k, replace=False)).astype(np.int32)
    write_csr_shard(path, data, indices, indptr, g,
                    {"row": np.arange(n, dtype=np.int32)})
    dense = np.zeros((n, g), np.float32)
    for i in range(n):
        for j in range(indptr[i], indptr[i + 1]):
            dense[i, indices[j]] += data[j]
    return dense


@pytest.fixture(scope="module")
def two_shards(tmp_path_factory):
    """Two 120-row CSR shards; returns (shard_paths, full_dense)."""
    rng = np.random.default_rng(0)
    root = tmp_path_factory.mktemp("shards")
    denses, paths = [], []
    for s in range(2):
        p = str(root / f"s{s}")
        denses.append(_write_csr(rng, p, 120, 32))
        paths.append(p)
    return paths, np.concatenate(denses)


# ------------------------------------------------------------- pure planner
def test_coalesce_and_split():
    # spans are (n, 2) int64 arrays throughout the planner (vectorized)
    runs = coalesce_rows(np.array([0, 1, 2, 7, 8, 20]))
    np.testing.assert_array_equal(runs, [(0, 3), (7, 9), (20, 21)])
    assert runs.dtype == np.int64 and runs.shape == (3, 2)
    np.testing.assert_array_equal(
        split_at_boundaries([(90, 110)], np.array([0, 100, 200])),
        [(90, 100), (100, 110)])
    np.testing.assert_array_equal(
        split_max_extent([(0, 10)], 4), [(0, 4), (4, 8), (8, 10)])
    # plan_reads composes all three; a run crossing a boundary AND the
    # extent cap splits at both
    plan = plan_reads(np.arange(95, 112), boundaries=np.array([0, 100, 200]),
                      max_extent_rows=8)
    np.testing.assert_array_equal(plan, [(95, 100), (100, 108), (108, 112)])
    # empty input -> empty (0, 2) plan
    assert coalesce_rows(np.array([], dtype=np.int64)).shape == (0, 2)


def test_block_cache_lru_byte_budget():
    cache = BlockCache(max_bytes=100)
    a = np.zeros(10, np.float32)  # 40 bytes
    cache.put(0, a, a.nbytes)
    cache.put(1, a, a.nbytes)
    assert cache.get(0) is a and cache.cur_bytes == 80
    # inserting a third 40B value must evict the LRU entry — key 1
    # (key 0 was touched by the get above)
    cache.put(2, a, a.nbytes)
    assert cache.evictions == 1 and cache.cur_bytes == 80
    assert cache.get(1) is None and cache.get(2) is a
    # an oversized value is not cached at all
    big = np.zeros(100, np.float32)
    cache.put(3, big, big.nbytes)
    assert cache.get(3) is None
    snap = cache.snapshot()
    assert snap["cur_bytes"] <= snap["max_bytes"]
    assert snap["hits"] == 2 and snap["misses"] == 2 and snap["insertions"] == 3


def test_block_cache_disabled():
    cache = BlockCache(max_bytes=0)
    cache.put(0, "x", 1)
    assert cache.get(0) is None and len(cache) == 0


# -------------------------------------------------------- registry coverage
def test_all_four_backends_reachable(two_shards, tmp_path):
    paths, full = two_shards
    rng = np.random.default_rng(1)
    rows = np.array([3, 150, 150, 119, 120, 0])

    single = open_collection(f"csr://{paths[0]}")
    assert single.schema["kind"] == "csr"
    assert np.allclose(single.fetch(np.array([5, 0, 5])).to_dense(),
                       full[[5, 0, 5]])

    sharded = open_collection("sharded-csr://" + ",".join(paths))
    got = sharded.fetch(rows)
    assert np.allclose(got.to_dense(), full[rows])
    assert np.array_equal(got.obs["row"], rows % 120)

    X = rng.normal(size=(300, 8)).astype(np.float32)
    cpath = str(tmp_path / "chunked")
    write_chunked_store(cpath, X, {"y": np.arange(300)}, chunk_rows=64)
    chunked = open_collection(f"chunked://{cpath}")
    assert np.allclose(chunked.fetch(np.array([299, 0, 64, 64])),
                       X[[299, 0, 64, 64]])
    # bare path sniffing finds the same backend
    assert open_collection(cpath).schema == chunked.schema

    tpath = str(tmp_path / "tok")
    generate_token_corpus(tpath, n_tokens=20_000, vocab_size=64,
                          n_sources=3, seed=2)
    tokens = open_collection(f"tokens://{tpath}?seq_len=32")
    ref = TokenStore(tpath, seq_len=32)[np.array([7, 7, 0])]
    got = tokens.fetch(np.array([7, 7, 0]))
    for k in ref:
        assert np.array_equal(got[k], ref[k])

    assert {"csr", "sharded-csr", "chunked", "tokens"} <= set(registered_schemes())
    with pytest.raises(ValueError):
        open_collection("nope://missing")
    with pytest.raises(ValueError):
        open_collection(f"tokens://{tpath}")  # seq_len required
    with pytest.raises(IndexError):
        sharded.fetch(np.array([10**9]))  # clear bounds error, not a crash
    with pytest.raises(IndexError):
        sharded.fetch(np.array([-1]))  # negatives must not wrap silently


# --------------------------------------------------- cross-shard coalescing
def test_cross_shard_fetch_is_two_runs_not_per_row(two_shards):
    paths, full = two_shards
    stats = IOStats()
    col = open_collection("sharded-csr://" + ",".join(paths),
                          iostats=stats, block_rows=16)
    rows = np.arange(104, 136)  # contiguous, spans the shard edge at 120
    got = col.fetch(rows)
    assert np.allclose(got.to_dense(), full[rows])
    # blocks 6..8 cover rows [96, 144); the planner merges them into one
    # global run and splits it only at the physical boundary: 2 reads,
    # not 32 per-row reads.
    assert stats.runs == 2
    assert stats.calls == 1  # accounting recorded once at the planner level


def test_max_extent_splits_oversized_runs(two_shards):
    paths, _ = two_shards
    stats = IOStats()
    col = open_collection(f"csr://{paths[0]}", iostats=stats,
                          block_rows=8, max_extent_rows=16)
    col.fetch(np.arange(0, 64))  # one 64-row run -> capped at 16 -> 4 reads
    assert stats.runs == 4


# ------------------------------------------------------- cache accounting
def test_cache_hits_and_eviction_accounting(two_shards):
    paths, full = two_shards
    stats = IOStats()
    col = open_collection(f"csr://{paths[0]}", iostats=stats,
                          block_rows=32, cache_bytes=1 << 20)
    col.fetch(np.arange(0, 32))  # block 0: miss, 1 run
    col.fetch(np.arange(0, 32))  # block 0 again: pure cache hit, 0 runs
    assert stats.runs == 1
    assert stats.cache_hits == 1 and stats.cache_misses == 1
    assert col.cache.hit_rate == 0.5
    # overlapping weighted-style refetch: one resident + one new block
    stats.reset()
    col.fetch(np.arange(16, 48))
    assert stats.cache_hits == 1 and stats.cache_misses == 1
    assert stats.runs == 1  # only block 1 ([32,64)) is read

    # byte budget forces LRU eviction, and bytes stay under budget
    one_block = col.nbytes_of(np.arange(0, 32))
    small = open_collection(f"csr://{paths[0]}", block_rows=32,
                            cache_bytes=int(one_block * 2.2))
    for lo in range(0, 120, 32):
        small.fetch(np.arange(lo, min(lo + 32, 120)))
    assert small.cache.evictions >= 1
    assert small.cache.cur_bytes <= small.cache.max_bytes
    # evicted first block rereads: a run, not a hit
    small.iostats.reset()
    small.fetch(np.arange(0, 32))
    assert small.iostats.runs == 1 and small.iostats.cache_hits == 0


def test_cache_disabled_still_plans(two_shards):
    paths, full = two_shards
    stats = IOStats()
    col = open_collection("sharded-csr://" + ",".join(paths), iostats=stats,
                          cache_bytes=0, block_rows=16)
    rows = np.arange(104, 136)
    assert np.allclose(col.fetch(rows).to_dense(), full[rows])
    assert stats.runs == 2 and stats.cache_hits == 0
    col.fetch(rows)  # no cache: reads again
    assert stats.runs == 4


def test_weighted_sampling_overlap_hits_cache(two_shards):
    """Blocks drawn with replacement across fetches hit memory, not disk."""
    paths, _ = two_shards
    stats = IOStats()
    col = open_collection("sharded-csr://" + ",".join(paths),
                          iostats=stats, block_rows=16)
    n = len(col)
    w = np.ones(n)
    ds = ScDataset(col, BlockWeightedSampling(block_size=16, weights=w),
                   batch_size=16, fetch_factor=2, seed=0)
    list(ds)
    list(ds)  # second epoch redraws blocks with replacement
    assert stats.cache_hits > 0
    # every block read at most once across both epochs: runs bounded by the
    # number of distinct cache blocks, far below the no-cache read count.
    # A block straddling a shard boundary costs one extra run when it is
    # first read in isolation (the shard edge at row 120 falls mid-block).
    straddles = sum(1 for off in (120,) if off % 16)
    assert stats.runs <= (n + 15) // 16 + straddles


# ------------------------------------------------------ protocol + dataset
def test_nbytes_of_matches_fetched_payload(two_shards):
    paths, _ = two_shards
    stats = IOStats()
    col = open_collection(f"csr://{paths[0]}", iostats=stats,
                          cache_bytes=0, block_rows=1)
    rows = np.arange(10, 30)
    est = col.nbytes_of(rows)
    col.fetch(rows)
    # data+indices payload dominates; read_range also moves indptr/obs, so
    # the estimate is a floor within the block rounding of this config
    assert 0 < est <= stats.bytes_read


def test_scdataset_default_callback_routes_through_planner(two_shards):
    paths, full = two_shards
    stats = IOStats()
    col = open_collection("sharded-csr://" + ",".join(paths), iostats=stats)
    ds = ScDataset(col, BlockShuffling(block_size=8), batch_size=16,
                   fetch_factor=2, seed=3,
                   batch_transform=lambda b: b.to_dense())
    batches = list(ds)
    assert stats.calls == len(batches) // 2  # one planner record per fetch
    # determinism: same seed over the raw store yields identical batches
    from repro.data import ShardedCSRStore
    raw = ScDataset(ShardedCSRStore(paths), BlockShuffling(block_size=8),
                    batch_size=16, fetch_factor=2, seed=3,
                    batch_transform=lambda b: b.to_dense())
    for a, b in zip(batches, raw):
        np.testing.assert_allclose(a, b)


def test_prefetch_pool_midepoch_resume_on_cached_collection(two_shards):
    """LoaderState checkpoint/restore through PrefetchPool + planner cache."""
    paths, _ = two_shards

    def mk():
        col = open_collection("sharded-csr://" + ",".join(paths),
                              block_rows=16, cache_bytes=1 << 20)
        return ScDataset(col, BlockShuffling(block_size=8), batch_size=8,
                         fetch_factor=2, seed=5,
                         batch_transform=lambda b: b.to_dense())

    full_run = [b.copy() for b in PrefetchPool(mk(), num_workers=2)]

    ds = mk()
    it = iter(PrefetchPool(ds, num_workers=2))
    consumed = [next(it).copy() for _ in range(5)]  # stop mid-fetch
    state = ds.state()
    assert state.batch_cursor == 1  # genuinely mid-fetch (5 = 2 fetches + 1)

    ds2 = mk()  # fresh collection: resume must not depend on cache contents
    ds2.load_state(state)
    rest = [b.copy() for b in PrefetchPool(ds2, num_workers=2)]
    assert len(consumed) + len(rest) == len(full_run)
    for got, want in zip(consumed + rest, full_run):
        np.testing.assert_allclose(got, want)
