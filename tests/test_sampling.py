"""Property tests for sampling strategies (paper §3.1/§3.3 invariants)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    BlockShuffling,
    BlockWeightedSampling,
    ClassBalancedSampling,
    Streaming,
    class_balanced_weights,
)

SIZES = st.integers(min_value=1, max_value=5000)
BLOCKS = st.sampled_from([1, 2, 3, 4, 7, 16, 64, 1000])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


@given(n=SIZES, b=BLOCKS, seed=SEEDS, epoch=st.integers(0, 5))
@settings(max_examples=60, deadline=None)
def test_block_shuffling_is_permutation(n, b, seed, epoch):
    idx = BlockShuffling(b).epoch_indices(n, seed, epoch)
    assert len(idx) == n
    assert np.array_equal(np.sort(idx), np.arange(n))


@given(n=SIZES, b=BLOCKS, seed=SEEDS)
@settings(max_examples=40, deadline=None)
def test_block_shuffling_preserves_within_block_order(n, b, seed):
    idx = BlockShuffling(b).epoch_indices(n, seed, 0)
    # the output decomposes into maximal consecutive runs; every run must be
    # a whole block: b-aligned start, length b (except the one ragged tail)
    breaks = np.flatnonzero(np.diff(idx) != 1)
    starts = np.concatenate(([0], breaks + 1))
    stops = np.concatenate((breaks + 1, [len(idx)]))
    short_runs = 0
    for a, z in zip(starts, stops):
        run_len = z - a
        assert idx[a] % b == 0  # runs start at block boundaries
        # merged adjacent blocks appear as longer runs -> length % b == 0,
        # except the single ragged tail block (n % b)
        if run_len % b != 0:
            short_runs += 1
            assert run_len % b == n % b
    assert short_runs <= 1


@given(n=SIZES, seed=SEEDS, buf=st.sampled_from([0, 1, 7, 100, 10000]))
@settings(max_examples=40, deadline=None)
def test_streaming_buffer_is_permutation(n, seed, buf):
    idx = Streaming(shuffle_buffer=buf).epoch_indices(n, seed, 0)
    assert np.array_equal(np.sort(idx), np.arange(n))
    if buf <= 1:
        assert np.array_equal(idx, np.arange(n))


@given(n=SIZES, b=BLOCKS, seed=SEEDS)
@settings(max_examples=30, deadline=None)
def test_determinism_across_calls(n, b, seed):
    s = BlockShuffling(b)
    a = s.epoch_indices(n, seed, 3)
    c = s.epoch_indices(n, seed, 3)
    assert np.array_equal(a, c)
    d = s.epoch_indices(n, seed, 4)
    if n > b:  # different epoch -> different order (w.h.p.)
        assert not np.array_equal(a, d) or n <= b


@given(seed=SEEDS)
@settings(max_examples=20, deadline=None)
def test_weighted_sampling_mass(seed):
    n = 8000
    b = 8
    # first half weight 3x the second half
    w = np.where(np.arange(n) < n // 2, 3.0, 1.0)
    idx = BlockWeightedSampling(block_size=b, weights=w).epoch_indices(n, seed, 0)
    frac_first = np.mean(idx < n // 2)
    assert 0.70 <= frac_first <= 0.80, frac_first  # expect 0.75


def test_class_balanced_weights():
    labels = np.array([0] * 900 + [1] * 90 + [2] * 10)
    w = class_balanced_weights(labels)
    mass = [w[labels == c].sum() for c in range(3)]
    assert np.allclose(mass, mass[0])


def test_class_balanced_sampling_rebalances():
    n = 9000
    labels = np.repeat([0, 1, 2], [8000, 900, 100])
    s = ClassBalancedSampling(block_size=1, labels=labels)
    idx = s.epoch_indices(n, 0, 0)
    counts = np.bincount(labels[idx], minlength=3) / len(idx)
    assert counts.min() > 0.25, counts  # each class ~1/3


def test_invalid_args():
    with pytest.raises(ValueError):
        BlockShuffling(0).epoch_indices(10, 0, 0)
    with pytest.raises(ValueError):
        BlockWeightedSampling(block_size=4, weights=np.array([-1.0, 1.0]))
    with pytest.raises(ValueError):
        BlockWeightedSampling(block_size=4, weights=np.zeros(5)).epoch_indices(5, 0, 0)


def test_block_weights_sum_not_mean_on_ragged_tail():
    """Regression: per-block draw probability is the SUM of member weights.

    n=5, b=2 -> blocks {0,1}, {2,3}, {4}.  Total mass 9; the ragged tail
    holds 5/9 of it.  A mean-per-block rule would give the tail 5/7 of the
    (unnormalized) mass per member and skew its inclusion probability.
    """
    w = np.array([1.0, 1.0, 1.0, 1.0, 5.0])
    s = BlockWeightedSampling(block_size=2, weights=w)
    p = s._block_weights(5)
    assert np.allclose(p, [2 / 9, 2 / 9, 5 / 9])
    # marginal inclusion probability of a sample is proportional to its
    # BLOCK's total weight (class docstring): the tail block carries mass 5,
    # each unit-weight block mass 2, so sample 4 appears 5/2 as often as
    # sample 0 — empirically confirmed.
    draws = np.concatenate(
        [s.epoch_indices(5, seed, 0) for seed in range(400)]
    )
    counts = np.bincount(draws, minlength=5).astype(float)
    assert counts[4] / counts[0] == pytest.approx(2.5, rel=0.2)
