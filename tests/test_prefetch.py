"""PrefetchPool: determinism, work stealing, straggler re-issue, errors."""
import time

import numpy as np
import pytest

from repro.core import BlockShuffling, PrefetchPool, ScDataset


@pytest.fixture(autouse=True)
def _witness(lock_order_witness):
    """Run every test here under the runtime lock-order witness: observed
    lock acquisition orders must be a subset of the static lock graph
    (tests/conftest.py; tools/analyze)."""
    yield


def _X(n=8192):
    return np.arange(n * 2, dtype=np.float32).reshape(n, 2)


def _mk(collection=None, **kw):
    defaults = dict(batch_size=32, fetch_factor=4, seed=3)
    defaults.update(kw)
    return ScDataset(collection if collection is not None else _X(),
                     BlockShuffling(8), **defaults)


@pytest.mark.parametrize("workers", [1, 2, 4])
def test_pool_matches_sync_iteration(workers):
    sync = [b.copy() for b in _mk()]
    pool = [b.copy() for b in PrefetchPool(_mk(), num_workers=workers)]
    assert len(sync) == len(pool)
    for a, b in zip(sync, pool):
        np.testing.assert_array_equal(a, b)


def test_multiple_workers_share_fetches():
    pool = PrefetchPool(_mk(fetch_factor=2), num_workers=2, max_outstanding=8)
    list(pool)
    wf = pool.stats["worker_fetches"]
    assert sum(wf.values()) == pool.stats["fetches"]
    assert len([w for w, c in wf.items() if c > 0]) >= 2


def test_straggler_speculative_reissue_dedups():
    class SlowStore:
        def __init__(self, X):
            self.X = X
            self.calls = 0

        def __len__(self):
            return len(self.X)

        def __getitem__(self, rows):
            self.calls += 1
            if self.calls == 2:
                time.sleep(0.6)
            return self.X[rows]

    ds = _mk(SlowStore(_X()), fetch_factor=2)
    pool = PrefetchPool(ds, num_workers=2, straggler_factor=2.0,
                        straggler_min_latency=0.02)
    batches = list(pool)
    ref = list(_mk(fetch_factor=2))
    assert len(batches) == len(ref)
    for a, b in zip(batches, ref):
        np.testing.assert_array_equal(a, b)
    assert pool.stats["speculative_reissues"] >= 1


def test_worker_errors_propagate():
    class BrokenStore:
        def __len__(self):
            return 4096

        def __getitem__(self, rows):
            raise IOError("disk on fire")

    with pytest.raises(IOError):
        list(PrefetchPool(_mk(BrokenStore()), num_workers=2))


def test_pool_resumes_from_cursor():
    ds = _mk()
    it = iter(PrefetchPool(ds, num_workers=2))
    consumed = [next(it) for _ in range(ds.fetch_factor * 2)]  # 2 full fetches
    state = ds.state()
    assert state.fetch_cursor >= 1
    ds2 = _mk()
    ds2.load_state(state)
    rest = [b.copy() for b in PrefetchPool(ds2, num_workers=2)]
    full = [b.copy() for b in _mk()]
    tail = full[state.fetch_cursor * ds.fetch_factor:]
    assert len(rest) == len(tail)
    for a, b in zip(tail, rest):
        np.testing.assert_array_equal(a, b)
