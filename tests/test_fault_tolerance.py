"""Fault tolerance: crash → restart from checkpoint is BITWISE identical to an
uninterrupted run (deterministic loader + checkpointed state + cursor)."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, flatten_tree
from repro.configs import smoke_config
from repro.distributed.fault import HeartbeatMonitor, run_with_restarts
from repro.launch.train import build_loader, train_loop
from repro.models import Model


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    return str(tmp_path_factory.mktemp("corpus"))


def _loader(corpus, seed=0):
    return build_loader(corpus, seq_len=32, batch=4, block_size=8,
                        fetch_factor=2, seed=seed, n_tokens=100_000,
                        vocab_size=128)


def _leaves(state):
    flat, _ = flatten_tree(state["params"])
    return {k: np.asarray(v) for k, v in flat.items()}


def test_crash_restart_bitwise_equal(corpus, tmp_path):
    model = Model(smoke_config("smollm-360m"))
    steps = 14

    # uninterrupted reference run
    ref = train_loop(model, _loader(corpus), steps=steps,
                     ckpt_dir=str(tmp_path / "ref"), ckpt_every=4, log_every=100)
    ref_params = _leaves(ref["final_state"])

    # crashing run: dies at step 9 (after the step-8 checkpoint), restarts
    ckpt = str(tmp_path / "crashy")

    def work(resume: bool):
        return train_loop(model, _loader(corpus), steps=steps, ckpt_dir=ckpt,
                          ckpt_every=4, log_every=100, resume=resume,
                          crash_after=None if resume else 9)

    restarts = []
    res = run_with_restarts(work, max_restarts=2,
                            on_restart=lambda n, e: restarts.append(str(e)))
    assert len(restarts) == 1 and "injected crash" in restarts[0]
    got_params = _leaves(res["final_state"])

    assert ref_params.keys() == got_params.keys()
    for k in ref_params:
        np.testing.assert_array_equal(ref_params[k], got_params[k]), k


def test_checkpoint_keep_n_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    state = {"w": jax.numpy.arange(8, dtype=jax.numpy.float32)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state, loader_state={"seed": 0, "epoch": 0, "fetch_cursor": s})
    assert mgr.all_steps() == [3, 4]
    restored, manifest = mgr.restore({"w": np.zeros(8, np.float32)})
    assert manifest["step"] == 4
    assert manifest["loader_state"]["fetch_cursor"] == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8))
    # no tmp dirs left behind
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp.")]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    state = {"w": np.ones(16, np.float32)}
    mgr.save(1, state, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [1]


def test_restore_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": np.ones(4, np.float32)})
    with pytest.raises(ValueError):
        mgr.restore({"w": np.zeros(5, np.float32)})


def test_run_with_restarts_gives_up():
    def work(resume):
        raise RuntimeError("always broken")

    with pytest.raises(RuntimeError):
        run_with_restarts(work, max_restarts=2)


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(timeout_s=0.05)
    hb.beat("w0")
    hb.beat("w1")
    assert set(hb.alive()) == {"w0", "w1"}
    import time

    time.sleep(0.08)
    hb.beat("w1")
    assert hb.suspects() == ["w0"]
    assert hb.alive() == ["w1"]


def test_heartbeat_suspect_recovers_on_beat():
    """suspect -> beat -> alive: a late rank rejoining clears its suspicion
    (the transition the ElasticSupervisor's rejoin path relies on)."""
    import time

    hb = HeartbeatMonitor(timeout_s=0.05)
    hb.beat("w0")
    time.sleep(0.08)
    assert hb.suspects() == ["w0"] and hb.alive() == []
    hb.beat("w0")  # rejoin
    assert hb.suspects() == [] and hb.alive() == ["w0"]
    time.sleep(0.08)  # ...and liveness keeps being re-evaluated after that
    assert hb.suspects() == ["w0"]
