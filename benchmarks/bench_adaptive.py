"""PR 5 — the adaptive I/O engine vs the best static configuration.

Claim under test: on weighted sampling over high-latency per-request storage
(the regime the paper's block sampling is pitched at), closing the loop
between IOStats and the planner knobs beats any hand-picked static
``(readahead, io_workers, admission)`` setting:

- **TinyLFU admission** (``admission="auto"``) keeps the hot redraw set
  resident when the sampled block working set exceeds ``cache_bytes`` —
  pure LRU churns it, so every redraw of a hot block pays another GET;
- **adaptive readahead** (``readahead="auto"``) withdraws staging under
  eviction pressure (it would evict the protected hot set) and deepens it
  when the cache has headroom;
- **autotuned io_workers** comes from the fitted per-request cost model
  (:func:`repro.core.autotune.recommend_concurrency`).

The fixture is the shared Tahoe-like dataset behind
``cloud://sharded-csr://...?profile=cross-region`` with ``latency_scale=0``:
no real sleeping, so the measurement is pure COUNTERS, and throughput is
*modeled* from them — ``t = requests * first_byte_s / min(W, max_inflight)
+ bytes / bw_Bps`` — which is deterministic and CI-stable.  Block weights
are Zipf-skewed (hot head, long tail) and the cache holds only ~a quarter
of the drawn working set, so admission policy is the decisive lever.

``run_adaptive`` writes machine-readable ``BENCH_PR5.json``; the smoke gate
(``benchmarks/run.py --smoke``) fails CI when the adaptive engine does not
beat the best static cell by ``ADAPTIVE_FLOOR`` (1.3x).

A second cell, ``coalesce_micro``, is the satellite microbenchmark for the
vectorized span planner: the old per-run Python-tuple ``coalesce_rows`` vs
the new ``(n, 2)`` array pipeline on a weighted-epoch-sized index set.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_DATA_DIR, N_CELLS, N_GENES, emit

from repro.core import BlockWeightedSampling
from repro.core.autotune import probe_collection, recommend_concurrency
from repro.data import CLOUD_PROFILES, IOStats, open_collection
from repro.data.synth import generate_tahoe_like
from repro.pipeline import Pipeline

PR5_JSON = os.environ.get("BENCH_PR5_JSON", "BENCH_PR5.json")
ADAPTIVE_FLOOR = 1.3

M = 64  # minibatch size
F = 8  # fetch factor -> 512-row fetches = 8 drawn blocks per fetch
BLOCK = 64  # sampling block == cache block (drawn blocks map 1:1)
PROFILE = "cross-region"
# cache ~= a tenth of the drawn block universe: the weighted working set
# EXCEEDS the budget, which is exactly the regime TinyLFU admission targets
CACHE_FRACTION = 0.1
# two-tier skew: a broad hot set of ~0.8x cache capacity carries HOT_MASS of
# the draw probability, the cold tail the rest.  This is the shape LRU loses
# on: every cold-tail draw (distinct, never redrawn) evicts a hot-set member
# it will need again, while frequency admission rejects the cold singletons.
# A steeper head (Zipf) would let LRU keep the few hottest blocks just as
# well, hiding the admission difference.
HOT_CACHE_FRACTION = 0.8
HOT_MASS = 0.8
ADAPTIVE_BATCHES = int(os.environ.get("BENCH_ADAPTIVE_BATCHES", "3600"))

# Hand-pickable static cells: the (readahead, io_workers) corners a user
# would reasonably choose, crossed with the STATIC admission policies.
# ``admission="auto"`` is deliberately absent — on this (non-streaming)
# weighted fixture "auto" IS the TinyLFU engine under test, not a static
# baseline; the static choices are plain LRU ("always", which pre-PR5
# "auto" degenerated to here) and no caching at all ("never").
STATIC_CELLS = (
    {"io_workers": 1, "readahead": 0, "admission": "always"},
    {"io_workers": 4, "readahead": 0, "admission": "always"},
    {"io_workers": 4, "readahead": 1, "admission": "always"},
    {"io_workers": 16, "readahead": 1, "admission": "always"},
    {"io_workers": 16, "readahead": 0, "admission": "never"},
)


def _block_weights(n: int, cache_blocks: int) -> np.ndarray:
    """Two-tier per-row weights, constant within each cache block.

    ``HOT_CACHE_FRACTION * cache_blocks`` hot blocks share ``HOT_MASS`` of
    the draw probability; the cold tail shares the rest.  Hot blocks are
    scattered over the row space (deterministic permutation) so their reads
    never coalesce into one extent — each redraw of an evicted block is a
    real GET.
    """
    n_blocks = (n + BLOCK - 1) // BLOCK
    hot = max(1, min(n_blocks - 1, int(cache_blocks * HOT_CACHE_FRACTION)))
    perm = np.random.default_rng(7).permutation(n_blocks)
    w_block = np.full(n_blocks, (1.0 - HOT_MASS) / (n_blocks - hot))
    w_block[perm[:hot]] = HOT_MASS / hot
    return w_block[np.arange(n, dtype=np.int64) // BLOCK]


def _open(cache_bytes: int, **knobs):
    stats = IOStats()
    col = open_collection(
        f"cloud://sharded-csr://{BENCH_DATA_DIR}?profile={PROFILE}"
        "&latency_scale=0",
        iostats=stats,
        cache_bytes=cache_bytes,
        block_rows=BLOCK,
        **knobs,
    )
    return col, stats


def _modeled_sps(stats: IOStats, samples: int, io_workers: int) -> float:
    """Samples/sec under the UNSCALED cross-region request model, from the
    measured counters alone: per-GET first-byte latency overlapped by
    ``min(io_workers, max_inflight)`` concurrent requests, plus streaming
    the read bytes.  Deterministic — no wall-clock noise in the gate."""
    prof = CLOUD_PROFILES[PROFILE]
    w_eff = min(max(1, int(io_workers)), prof.max_inflight)
    t = (stats.requests * prof.first_byte_s / w_eff
         + stats.bytes_read / prof.bw_Bps)
    return samples / max(t, 1e-12)


def _run_cell(name: str, *, cache_bytes: int, weights: np.ndarray,
              io_workers: int, readahead, admission: str,
              cross_epoch: bool = False) -> dict:
    col, stats = _open(cache_bytes, io_workers=io_workers,
                       readahead=readahead, admission=admission)
    pipe = (
        Pipeline.from_collection(col)
        .strategy(BlockWeightedSampling(block_size=BLOCK, weights=weights))
        .batch(M, fetch_factor=F)
        .seed(0)
        .prefetch(cross_epoch=cross_epoch)
        .build()
    )
    n = 0
    t0 = time.perf_counter()
    for _ in pipe.epochs(8):  # more epochs than the drain can consume
        n += 1
        if n >= ADAPTIVE_BATCHES:
            break
    cpu_wall = time.perf_counter() - t0
    samples = n * M
    out = {
        "samples": samples,
        "sps_modeled": _modeled_sps(stats, samples, io_workers),
        "requests": stats.requests,
        "requests_per_sample": stats.requests / max(1, stats.rows),
        "bytes_read": stats.bytes_read,
        "cache_hit_rate": stats.cache_hit_rate,
        "prefetched": stats.prefetched,
        "adm_bypassed": stats.adm_bypassed,
        "adm_rejected": stats.adm_rejected,
        "cpu_wall_s": cpu_wall,
        "io_workers": io_workers,
        "readahead": readahead,
        "admission": admission,
    }
    cstats = col.stats()
    if "readahead" in cstats:
        out["readahead_controller"] = cstats["readahead"]
    col.release()
    emit(name, 1e6 / max(out["sps_modeled"], 1e-9),
         f"sps_modeled={out['sps_modeled']:.1f};"
         f"req_per_sample={out['requests_per_sample']:.4f};"
         f"hit_rate={out['cache_hit_rate']:.2f};io_workers={io_workers};"
         f"readahead={readahead};admission={admission}")
    return out


def coalesce_micro() -> dict:
    """Vectorized (n, 2)-span planner vs the old per-run Python-tuple build
    on a weighted-epoch-sized index set (satellite microbenchmark)."""
    from repro.data.readplan import coalesce_rows

    def coalesce_rows_tuples(sorted_unique):
        # the pre-PR5 implementation, kept inline as the baseline
        if len(sorted_unique) == 0:
            return []
        breaks = np.flatnonzero(np.diff(sorted_unique) != 1)
        firsts = np.concatenate(([0], breaks + 1))
        lasts = np.concatenate((breaks, [len(sorted_unique) - 1]))
        return [
            (int(sorted_unique[a]), int(sorted_unique[b]) + 1)
            for a, b in zip(firsts, lasts)
        ]

    rng = np.random.default_rng(0)
    # ~a weighted epoch of drawn blocks: 100k scattered 16-row blocks
    starts = np.sort(rng.integers(0, 50_000_000, size=100_000)) * 16
    rows = np.unique((starts[:, None] + np.arange(16)[None, :]).reshape(-1))

    def best_of(fn, reps=5):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn(rows)
            best = min(best, time.perf_counter() - t0)
        return best

    t_old = best_of(coalesce_rows_tuples)
    t_new = best_of(coalesce_rows)
    ref = coalesce_rows_tuples(rows)
    got = coalesce_rows(rows)
    identical = (len(ref) == len(got)
                 and bool(np.array_equal(np.asarray(ref), got)))
    speedup = t_old / max(t_new, 1e-12)
    emit("readplan_coalesce_micro", t_new * 1e6,
         f"rows={len(rows)};runs={len(got)};t_tuples_ms={t_old*1e3:.1f};"
         f"t_vector_ms={t_new*1e3:.1f};speedup={speedup:.1f}x;"
         f"identical={identical}")
    return {
        "rows": int(len(rows)),
        "runs": int(len(got)),
        "t_tuples_s": t_old,
        "t_vectorized_s": t_new,
        "speedup": speedup,
        "identical": identical,
    }


def run_adaptive(write_json: bool = True) -> dict:
    generate_tahoe_like(BENCH_DATA_DIR, n_cells=N_CELLS, n_genes=N_GENES,
                        seed=0)
    probe_col, _ = _open(cache_bytes=0)
    n = len(probe_col)
    n_blocks = (n + BLOCK - 1) // BLOCK
    cache_blocks = max(4, int(CACHE_FRACTION * n_blocks))
    block_bytes = probe_col.avg_row_bytes * BLOCK
    cache_bytes = int(cache_blocks * block_bytes)
    weights = _block_weights(n, cache_blocks)

    # fit the per-request cost model through the planner; latency_scale=0
    # means the fit sees only CPU, so anchor c_seek at the profile's
    # first-byte floor (it is slept on every real GET) before asking for
    # the concurrency pick — same anchoring as the fig2 cloud grid.
    model = probe_collection(probe_col, probes=3, probe_rows=512)
    model.c_seek = max(model.c_seek, CLOUD_PROFILES[PROFILE].first_byte_s)
    probe_col.release()
    rec_workers, rec_readahead = recommend_concurrency(
        model, batch_size=M, fetch_factor=F, block_size=BLOCK
    )
    emit("adaptive_recommend_concurrency", 0.0,
         f"io_workers={rec_workers};readahead={rec_readahead};"
         f"c_seek_ms={model.c_seek*1e3:.1f}")

    statics = {}
    for cell in STATIC_CELLS:
        name = (f"adaptive_static_w{cell['io_workers']}_r{cell['readahead']}"
                f"_{cell['admission']}")
        statics[name] = _run_cell(
            name, cache_bytes=cache_bytes, weights=weights, **cell,
        )
    adaptive = _run_cell(
        "adaptive_engine", cache_bytes=cache_bytes, weights=weights,
        io_workers=rec_workers, readahead=rec_readahead, admission="auto",
        cross_epoch=True,
    )
    best_name, best = max(statics.items(), key=lambda kv: kv[1]["sps_modeled"])
    speedup = adaptive["sps_modeled"] / max(best["sps_modeled"], 1e-9)
    ok = speedup >= ADAPTIVE_FLOOR
    emit("adaptive_vs_best_static", 0.0,
         f"speedup={speedup:.2f}x;floor={ADAPTIVE_FLOOR}x;"
         f"best_static={best_name};pass={ok}")
    micro = coalesce_micro()
    out = {
        "bench": "adaptive_io_engine",
        "fixture": {
            "n_cells": n,
            "profile": PROFILE,
            "block_rows": BLOCK,
            "batch_size": M,
            "fetch_factor": F,
            "hot_cache_fraction": HOT_CACHE_FRACTION,
            "hot_mass": HOT_MASS,
            "cache_bytes": cache_bytes,
            "working_set_bytes": int(n_blocks * block_bytes),
            "batches": ADAPTIVE_BATCHES,
        },
        "recommended": {"io_workers": rec_workers,
                        "readahead": rec_readahead},
        "static": statics,
        "best_static": best_name,
        "adaptive": adaptive,
        "speedup": speedup,
        "floor": ADAPTIVE_FLOOR,
        "pass": bool(ok),
        "coalesce_micro": micro,
    }
    if write_json:
        with open(PR5_JSON, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"# wrote {PR5_JSON}")
    return out


def run() -> dict:
    return run_adaptive(write_json=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
