"""Paper Table 2 / Appendix E — multi-worker throughput and entropy.

Claim under test: at equal total buffer memory, concurrent workers beat a
single worker (the paper: b=16,f=256,w=4 at 4614 sps vs single-core
b=16,f=1024 at 1854 sps — a 2.5x from parallel transforms + I/O coalescing);
batch entropy is unaffected by worker count (deterministic fetch plan).

This container has ONE core, so wall-clock parallel speedup is not
reproducible; what IS validated here: (1) the work-stealing pool yields the
exact same batches as synchronous iteration (the worker-count rows still
fetch directly from the sharded store — the one remaining direct-read
measurement, kept as the pre-planner baseline), (2) per-worker fetch counts
balance, (3) speculative straggler re-issue fires and dedups under an
injected slow worker, (4) entropy invariance across worker counts, and
(5) — through the unified backend layer — pool workers over a planned
collection stop serializing behind one another's reads once ``io_workers``
executes the planner's miss extents concurrently (the ``pool_async`` row;
same shared equal-work cell as fig2's async rows, identical delivered
batches, slept per-read storage latency).

All rows construct through the Pipeline API (``Pipeline.from_collection``
for the raw-store baseline rows, the shared ``async_cell_pipeline`` for the
planned ones); the worker pool itself — including the straggler knobs — is
declared via ``.prefetch(...)`` and reached through ``pipe.last_pool``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import async_equal_work, dataset, emit, timed_samples_per_sec

from repro.core.theory import mean_batch_entropy
from repro.pipeline import Pipeline

M = 64


def run() -> dict:
    store, stats = dataset()
    out = {}
    ent = {}
    for workers in (1, 2, 4):
        pipe = (
            Pipeline.from_collection(store)
            .strategy("block", block_size=16)
            .batch(M, fetch_factor=64)
            .seed(0)
            .prefetch(workers=workers)
            .build()
        )
        pool = iter(pipe)  # a PrefetchPool iterator (prefetch_workers > 0)
        stats.reset()
        plates, n = [], 0
        t0 = time.perf_counter()
        for batch in pool:
            plates.append(np.asarray(batch.obs["plate"]))
            n += 1
            if n >= 128:
                break
        wall = time.perf_counter() - t0
        mean, std = mean_batch_entropy(plates)
        ent[workers] = mean
        wf = dict(pipe.last_pool.stats["worker_fetches"])
        out[workers] = {"sps_wall": n * M / wall, "entropy": mean}
        emit(f"table2_w{workers}_b16_f64", 1e6 / (n * M / wall),
             f"sps_wall={n*M/wall:.0f};entropy={mean:.2f}+-{std:.2f};"
             f"worker_fetches={wf};paper_b16_f64_w4=3156sps_H3.58")

    # entropy invariance across worker counts (determinism)
    spread = max(ent.values()) - min(ent.values())
    emit("table2_entropy_invariance", 0.0,
         f"spread={spread:.3f};claim=identical_batches_any_worker_count")

    # straggler mitigation: inject a slow fetch via a throttled callback
    class SlowStore:
        def __init__(self, store):
            self.store = store
            self.calls = 0

        def __len__(self):
            return len(self.store)

        def __getitem__(self, rows):
            self.calls += 1
            if self.calls == 3:  # third fetch stalls
                time.sleep(1.0)
            return self.store[rows]

    slow_pipe = (
        Pipeline.from_collection(SlowStore(store))
        .strategy("block", block_size=16)
        .batch(M, fetch_factor=16)
        .seed(0)
        .prefetch(workers=2, straggler_factor=2.0, straggler_min_latency=0.05)
        .build()
    )
    n = 0
    for batch in slow_pipe:
        n += 1
        if n >= 64:
            break
    pstats = slow_pipe.last_pool.stats
    emit("table2_straggler_reissue", 0.0,
         f"speculative_reissues={pstats['speculative_reissues']};"
         f"duplicate_completions={pstats['duplicate_completions']};"
         f"batches_ok={n}")

    # pool workers over SYNC vs ASYNC planned collections, slept latency:
    # with io_workers the pool's fetches stop serializing behind one
    # another's planner reads (Appendix E at the planner level).  Same
    # shared comparison cell as fig2's async rows (common.ASYNC_CELL),
    # equal work, identical delivered batches.
    pa = {}
    for mode, kw in (("sync", dict(io_workers=1, readahead=0)),
                     ("async", dict(io_workers=4, readahead=1))):
        pa[mode] = async_equal_work(n_batches=64, batch_size=M,
                                    num_workers=2, **kw)["sps_wall"]
    emit("table2_pool_async_planner", 1e6 / pa["async"],
         f"sync_sps={pa['sync']:.0f};async_sps={pa['async']:.0f};"
         f"speedup={pa['async'] / max(pa['sync'], 1e-9):.2f}x;workers=2;io_workers=4")
    out["pool_async"] = pa
    return out


if __name__ == "__main__":
    argparse.ArgumentParser(
        description=(
            "Paper Table 2 / Appendix E: PrefetchPool worker scaling, "
            "determinism and entropy invariance, straggler re-issue "
            "dedup, and pool-over-planned-collection sync-vs-async "
            "(io_workers) throughput under slept storage latency."
        ),
        epilog="Env knobs: BENCH_N_CELLS, BENCH_SIM_SCALE, BENCH_DATA_DIR.",
    ).parse_args()
    print("name,us_per_call,derived")
    run()
