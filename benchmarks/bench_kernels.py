"""Kernel microbench — ref-path timings + interpret-mode validation deltas.

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock here measures the jnp REFERENCE path (what the dry-run lowers);
the kernel rows report max|err| vs the oracle across a shape sweep — the
quantity that must be 0-ish for the TPU deployment to be trustworthy.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

from repro.kernels import ref
from repro.kernels.csr_to_dense import ell_to_dense
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssm_scan


def _time(fn, *args, reps=5, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> None:
    rng = np.random.default_rng(0)

    # --- flash attention
    B, H, Hkv, S, D = 1, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(0, 1, (B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
    us = _time(ref.flash_attention_ref, q, k, v, causal=True)
    out_i = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                            interpret=True)
    err = float(jnp.max(jnp.abs(out_i - ref.flash_attention_ref(q, k, v, causal=True))))
    emit("kernel_flash_attention", us, f"ref_path_us={us:.0f};interp_max_err={err:.2e}")

    # --- ELL decompress
    R, K, G = 256, 64, 2048
    vals = jnp.asarray(rng.normal(0, 1, (R, K)), jnp.float32)
    cols = jnp.asarray(rng.integers(-1, G, (R, K)), jnp.int32)
    us = _time(lambda v_, c_: ref.ell_to_dense_ref(v_, c_, G), vals, cols)
    out_i = ell_to_dense(vals, cols, n_cols=G, block_rows=8, block_cols=256,
                         interpret=True)
    err = float(jnp.max(jnp.abs(out_i - ref.ell_to_dense_ref(vals, cols, G))))
    emit("kernel_ell_to_dense", us, f"ref_path_us={us:.0f};interp_max_err={err:.2e}")

    # --- SSM scan
    Bsz, Sq, Dm, N = 2, 256, 128, 16
    x = jnp.asarray(rng.normal(0, 1, (Bsz, Sq, Dm)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (Bsz, Sq, Dm)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (Dm, N)), jnp.float32)
    Bc = jnp.asarray(rng.normal(0, 1, (Bsz, Sq, N)), jnp.float32)
    Cc = jnp.asarray(rng.normal(0, 1, (Bsz, Sq, N)), jnp.float32)
    Dd = jnp.asarray(rng.normal(0, 1, (Dm,)), jnp.float32)
    us = _time(ref.ssm_scan_ref, x, dt, A, Bc, Cc, Dd)
    y_i, h_i = ssm_scan(x, dt, A, Bc, Cc, Dd, block_d=64, chunk=64, interpret=True)
    y_r, h_r = ref.ssm_scan_ref(x, dt, A, Bc, Cc, Dd)
    err = max(float(jnp.max(jnp.abs(y_i - y_r))), float(jnp.max(jnp.abs(h_i - h_r))))
    emit("kernel_ssm_scan", us, f"ref_path_us={us:.0f};interp_max_err={err:.2e}")


if __name__ == "__main__":
    run()
