"""Paper §5 "automated profiling to recommend (b, f)" — implemented and swept.

For three storage regimes (SATA-SSD/HDF5 as calibrated from the paper's
baseline, NVMe, cloud object store) the autotuner maximizes modeled
throughput under a 2 GB buffer budget and a 0.1-bit entropy-slack diversity
constraint (Cor 3.3).  Sanity: the recommendation must beat naive random
sampling by >100x on SATA and respect both constraints.
"""
from __future__ import annotations

from benchmarks.common import dataset, emit

from repro.core.autotune import IOCostModel, probe_io_cost, recommend
from repro.data import CLOUD_OBJECT, NVME_SSD, SATA_SSD


def run() -> dict:
    store, _ = dataset(simulate_sata=False)
    row_bytes = 50_000  # Tahoe-scale sparse row (~62k genes)
    out = {}
    for model in (SATA_SSD, NVME_SSD, CLOUD_OBJECT):
        cost = IOCostModel(c0=model.seek_s, c_seek=model.seek_s,
                           c_byte=1.0 / model.bw_Bps, row_bytes=row_bytes)
        rec = recommend(cost, batch_size=64, num_classes=14,
                        mem_budget_bytes=2e9, entropy_slack_bits=0.1)
        naive = cost.samples_per_sec(64, 1, 1)
        out[model.name] = rec
        emit(f"autotune_{model.name}", 1e6 / rec.modeled_samples_per_sec,
             f"b={rec.block_size};f={rec.fetch_factor};"
             f"sps={rec.modeled_samples_per_sec:.0f};"
             f"speedup_vs_random={rec.modeled_samples_per_sec/naive:.0f}x;"
             f"buffer={rec.buffer_bytes/1e6:.0f}MB")

    # probe a REAL backend (the mmap CSR store) and recommend for it
    probed = probe_io_cost(lambda idx: store[idx], len(store),
                           row_bytes=store.avg_row_bytes, probes=2)
    rec = recommend(probed, batch_size=64, num_classes=14,
                    mem_budget_bytes=2e9, entropy_slack_bits=0.1)
    emit("autotune_probed_mmap", 1e6 / rec.modeled_samples_per_sec,
         f"b={rec.block_size};f={rec.fetch_factor};"
         f"c0={probed.c0*1e6:.0f}us;c_seek={probed.c_seek*1e6:.1f}us")
    return out


if __name__ == "__main__":
    run()
