"""Paper §5 "automated profiling to recommend (b, f)" — implemented and swept.

For three storage regimes (SATA-SSD/HDF5 as calibrated from the paper's
baseline, NVMe, cloud object store) the autotuner maximizes modeled
throughput under a 2 GB buffer budget and a 0.1-bit entropy-slack diversity
constraint (Cor 3.3).  Sanity: the recommendation must beat naive random
sampling by >100x on SATA and respect both constraints.
"""
from __future__ import annotations

from benchmarks.common import dataset, emit, planned_dataset

from repro.core.autotune import IOCostModel, probe_collection, probe_io_cost, recommend
from repro.data import CLOUD_OBJECT, NVME_SSD, SATA_SSD


def run() -> dict:
    store, _ = dataset(simulate_sata=False)
    row_bytes = 50_000  # Tahoe-scale sparse row (~62k genes)
    out = {}
    for model in (SATA_SSD, NVME_SSD, CLOUD_OBJECT):
        cost = IOCostModel(c0=model.seek_s, c_seek=model.seek_s,
                           c_byte=1.0 / model.bw_Bps, row_bytes=row_bytes)
        rec = recommend(cost, batch_size=64, num_classes=14,
                        mem_budget_bytes=2e9, entropy_slack_bits=0.1)
        naive = cost.samples_per_sec(64, 1, 1)
        out[model.name] = rec
        emit(f"autotune_{model.name}", 1e6 / rec.modeled_samples_per_sec,
             f"b={rec.block_size};f={rec.fetch_factor};"
             f"sps={rec.modeled_samples_per_sec:.0f};"
             f"speedup_vs_random={rec.modeled_samples_per_sec/naive:.0f}x;"
             f"buffer={rec.buffer_bytes/1e6:.0f}MB")

    # probe a REAL backend (the mmap CSR store) and recommend for it
    probed = probe_io_cost(lambda idx: store[idx], len(store),
                           row_bytes=store.avg_row_bytes, probes=2)
    rec = recommend(probed, batch_size=64, num_classes=14,
                    mem_budget_bytes=2e9, entropy_slack_bits=0.1)
    emit("autotune_probed_mmap", 1e6 / rec.modeled_samples_per_sec,
         f"b={rec.block_size};f={rec.fetch_factor};"
         f"c0={probed.c0*1e6:.0f}us;c_seek={probed.c_seek*1e6:.1f}us")

    # planner-aware probe (PR 2): fit on PLANNED runs through the unified
    # layer, cached vs uncached.  With the cache absorbing redraw probes the
    # recommendation reserves the cache's bytes out of the buffer budget —
    # a smaller fetch factor than the cache-blind probe of the same store.
    budget = 900e6
    cache_bytes = 448 << 20
    col_cold, _ = planned_dataset(simulate_sata=False, cache_bytes=0)
    col_warm, _ = planned_dataset(simulate_sata=False, cache_bytes=cache_bytes)
    for name, col in (("uncached", col_cold), ("cached", col_warm)):
        model = probe_collection(col, probes=2)
        # Tahoe-scale rows (the probe fixture's rows are tiny; the paper's
        # regime is ~50KB sparse rows) so the memory budget is meaningful
        model.row_bytes = 50_000
        r = recommend(model, batch_size=64, num_classes=14,
                      mem_budget_bytes=budget, entropy_slack_bits=0.1)
        out[f"planner_{name}"] = r
        emit(f"autotune_planner_{name}", 1e6 / r.modeled_samples_per_sec,
             f"b={r.block_size};f={r.fetch_factor};"
             f"hit_rate={model.hit_rate:.2f};"
             f"runs_per_sample={model.runs_per_sample:.4f};"
             f"cache_reserved={r.cache_reserved_bytes/1e6:.0f}MB")
    fc = out["planner_cached"].fetch_factor
    fu = out["planner_uncached"].fetch_factor
    emit("autotune_planner_f_shrinks", 0.0,
         f"f_cached={fc};f_uncached={fu};shrinks={fc < fu}")
    return out


if __name__ == "__main__":
    run()
