"""Paper Fig. 4 + §3.4 — minibatch plate-entropy vs (block_size, fetch_factor).

Claims under test (paper Eq. 5 and §4.3, m=64, 14 Tahoe plates, H(p)=3.78):
  - bounds: 1.43 <= E[H] <= 3.63 for b=16;
  - b=16, f=1   -> 1.76 +/- 0.33 (near lower bound);
  - b=16, f=256 -> 3.61 +/- 0.08 (near upper bound / random sampling 3.62);
  - entropy collapses to ~0 when b >= m*f;
  - theory (Thms 3.1/3.2, Cor 3.3) matches measurement.

Built through the Pipeline/DataSpec surface (PR 8 — the last hand-wired
benchmark), with ``.diversity(obs="plate")`` attached: every cell
cross-checks its measured entropy grid against the LIVE ``div_*`` IOStats
counters, so the offline Fig. 4 measurement and the runtime observatory can
never drift apart silently.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_DATA_DIR, dataset, emit

from repro.core.theory import (
    distribution_entropy,
    entropy_bounds,
    mean_batch_entropy,
)
from repro.data import IOStats
from repro.pipeline import Pipeline

M = 64
GRID_B = (1, 4, 16, 64, 256, 1024)
GRID_F = (1, 4, 16, 64, 256)
N_BATCHES = 160


def measure_entropy(b: int, f: int) -> tuple[float, float]:
    """Mean/std batch plate-entropy of cell (b, f), Pipeline-built.

    Drains a FULL-FETCH multiple of batches (``fetch`` materializes — and
    the DiversityMonitor observes — all f minibatches of a fetch at once),
    then asserts the live ``div_*`` counters agree exactly with the offline
    measurement over the same batches.
    """
    stats = IOStats()
    pipe = (
        Pipeline.from_uri("sharded-csr://" + BENCH_DATA_DIR, iostats=stats)
        .strategy("block", block_size=b)
        .batch(M, fetch_factor=f)
        .seed(0)
        .diversity(obs="plate")
        .build(batch_transform=lambda bb: np.asarray(bb.obs["plate"]))
    )
    n_target = -(-N_BATCHES // f) * f  # ceil to a fetch boundary
    plates = []
    for i, pl in enumerate(iter(pipe)):
        plates.append(np.asarray(pl))
        if i + 1 >= n_target:
            break
    pipe.close()
    mean, std = mean_batch_entropy(plates)
    snap = stats.snapshot()
    assert snap["div_batches"] == len(plates), (
        f"diversity counters saw {snap['div_batches']} batches, "
        f"delivered {len(plates)} (b={b}, f={f})"
    )
    live_mean = snap["div_entropy_sum"] / snap["div_batches"]
    assert np.isclose(live_mean, mean, rtol=1e-9, atol=1e-12), (
        f"live entropy {live_mean} != measured {mean} (b={b}, f={f})"
    )
    return mean, std


def run() -> dict:
    store, _ = dataset(simulate_sata=False)  # ensures the fixture exists
    sizes = np.array([len(s) for s in store.shards], dtype=np.float64)
    p = sizes / sizes.sum()
    Hp = distribution_entropy(p)
    emit("fig4_plate_distribution_entropy", 0.0,
         f"H(p)={Hp:.3f};paper=3.78")

    results = {}
    for b in GRID_B:
        for f in GRID_F:
            mean, std = measure_entropy(b, f)
            lo, hi = entropy_bounds(p, M, b)
            in_bounds = lo - 3 * max(std, 0.05) <= mean <= hi + 3 * max(std, 0.05)
            results[(b, f)] = (mean, std)
            emit(
                f"fig4_entropy_b{b}_f{f}", 0.0,
                f"H={mean:.2f}+-{std:.2f};bounds=[{lo:.2f},{hi:.2f}];"
                f"in_bounds={in_bounds}",
            )
    # headline paper numbers
    m1 = results[(16, 1)]
    m256 = results[(16, 256)]
    emit("fig4_paper_b16_f1", 0.0,
         f"H={m1[0]:.2f}+-{m1[1]:.2f};paper=1.76+-0.33")
    emit("fig4_paper_b16_f256", 0.0,
         f"H={m256[0]:.2f}+-{m256[1]:.2f};paper=3.61+-0.08")
    rnd, _ = measure_entropy(1, 4)
    emit("fig4_random_sampling", 0.0, f"H={rnd:.2f};paper=3.62")
    emit("fig4_live_counter_agreement", 0.0,
         f"cells={len(results) + 1};div_counters=exact")
    return {"results": {f"{b}x{f}": v for (b, f), v in results.items()}, "Hp": Hp}


if __name__ == "__main__":
    run()
