"""Elastic data fabric vs isolated rank loaders — kill/resize continuation
and the cross-rank read-dedup dividend.

Two claims from the elastic fabric (docs/architecture.md, "Elastic fabric"):

- **bitwise continuation** — a world that loses a rank mid-epoch and is
  resized N→M→N delivers, across all ranks and phases, EXACTLY the
  never-resized global stream (fetches are pure in ``(seed, epoch, gid)``,
  so merged ``remaining`` lists re-home the stream losslessly);
- **cross-rank dedup (RINAS)** — co-located rank loaders sharing ONE
  collection (one block cache + rendezvous table, each rank tagged through
  a :class:`RankView`) issue strictly fewer backend requests and bytes per
  sample than the same ranks on isolated collections splitting the same
  cache budget, with the dividend attributed in ``shared_rank_hits``.

Both arms run the cloud-profiled fixture (``cloud://`` over the shared
Tahoe-like store, ``latency_scale=0`` — request accounting without real
sleeps).  Streams are compared by per-batch digest keyed on
``(global_fetch_id, batch_index)`` so the three runs (reference, elastic,
isolated) are checked bitwise without holding three dense epochs in memory.

``run_elastic`` writes machine-readable ``BENCH_PR10.json``; smoke gate #8
(``python -m benchmarks.run --smoke``) exits nonzero unless the kill/resize
stream is bitwise identical to the reference AND the shared-collection arm
issues strictly fewer requests and bytes.
"""
from __future__ import annotations

import hashlib
import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_DATA_DIR, N_CELLS, N_GENES, emit
from repro.core import BlockShuffling, ScDataset
from repro.data import SATA_SSD, IOStats, generate_tahoe_like, open_collection
from repro.distributed.elastic import ElasticFabric, tagged_batches

PR10_JSON = os.environ.get("BENCH_PR10_JSON", "BENCH_PR10.json")

WORLD = 3
BATCH_SIZE = 64
FETCH_FACTOR = 8
BLOCK_SIZE = 16
#: batches each rank delivers between kill/resize events
PHASE_BATCHES = int(os.environ.get("BENCH_ELASTIC_PHASE", "8"))
#: total block-cache budget, split evenly in the isolated arm
CACHE_TOTAL = 48 << 20

DS_KW = dict(batch_size=BATCH_SIZE, fetch_factor=FETCH_FACTOR, seed=0)


def _uri() -> str:
    return (
        f"cloud://sharded-csr://{BENCH_DATA_DIR}"
        "?profile=same-region&latency_scale=0"
    )


def _digest(batch) -> str:
    h = hashlib.blake2b(digest_size=16)
    if hasattr(batch, "indptr"):  # CSRBatch
        for a in (batch.data, batch.indices, batch.indptr):
            h.update(np.ascontiguousarray(a).tobytes())
    else:
        h.update(np.ascontiguousarray(batch).tobytes())
    return h.hexdigest()


def _drain_tagged(ds, got: dict, limit=None) -> int:
    n = 0
    for gid, j, b in tagged_batches(ds, limit=limit):
        key = (gid, j)
        assert key not in got, f"duplicate delivery of {key}"
        got[key] = _digest(b)
        n += 1
    return n


def _interleave(fab: ElasticFabric, got: dict, limit=None) -> int:
    """Round-robin the ranks batch-by-batch — the co-located schedule."""
    its = {r: tagged_batches(ds, limit=limit)
           for r, ds in sorted(fab.loaders.items())}
    n = 0
    while its:
        for r in list(its):
            try:
                gid, j, b = next(its[r])
            except StopIteration:
                del its[r]
                continue
            key = (gid, j)
            assert key not in got, f"duplicate delivery of {key}"
            got[key] = _digest(b)
            n += 1
    return n


def _reference() -> dict:
    col = open_collection(_uri(), iostats=IOStats(), cache_bytes=CACHE_TOTAL)
    ds = ScDataset(col, BlockShuffling(BLOCK_SIZE), rank=0, world_size=1,
                   **DS_KW)
    ref: dict = {}
    _drain_tagged(ds, ref)
    return ref


def _elastic_arm() -> tuple:
    """world 3 → kill(1) → resize(2) → resize(3) → drain, ONE collection."""
    stats = IOStats(simulate=SATA_SSD, simulate_scale=0.0)
    col = open_collection(_uri(), iostats=stats, cache_bytes=CACHE_TOTAL,
                          io_workers=2)
    fab = ElasticFabric(col, world_size=WORLD,
                        strategy=BlockShuffling(BLOCK_SIZE), **DS_KW)
    got: dict = {}
    t0 = time.perf_counter()
    _interleave(fab, got, limit=PHASE_BATCHES)
    fab.kill(1)
    fab.resize(WORLD - 1)
    _interleave(fab, got, limit=PHASE_BATCHES)
    fab.resize(WORLD)
    _interleave(fab, got)
    wall = time.perf_counter() - t0
    samples = len(got) * BATCH_SIZE
    modeled = wall + stats.modeled_s
    return got, {
        "schedule": f"{WORLD} -> kill(1) -> {WORLD - 1} -> {WORLD}",
        "samples": samples,
        "wall_s": wall,
        "modeled_total_s": modeled,
        "sps_modeled": samples / max(modeled, 1e-9),
        "requests": stats.requests,
        "bytes_read": stats.bytes_read,
        "cache_hits": stats.cache_hits,
        "shared_rank_hits": stats.shared_rank_hits,
        "requests_per_sample": stats.requests / max(samples, 1),
    }


def _isolated_arm() -> tuple:
    """The same three ranks, each on its OWN collection and cache slice."""
    got: dict = {}
    wall = 0.0
    per = [None] * WORLD
    for r in range(WORLD):
        stats = IOStats(simulate=SATA_SSD, simulate_scale=0.0)
        col = open_collection(_uri(), iostats=stats,
                              cache_bytes=CACHE_TOTAL // WORLD, io_workers=2)
        ds = ScDataset(col, BlockShuffling(BLOCK_SIZE), rank=r,
                       world_size=WORLD, **DS_KW)
        t0 = time.perf_counter()
        _drain_tagged(ds, got)
        wall += time.perf_counter() - t0
        per[r] = stats
    samples = len(got) * BATCH_SIZE
    modeled = wall + sum(s.modeled_s for s in per)
    requests = sum(s.requests for s in per)
    return got, {
        "samples": samples,
        "wall_s": wall,
        "modeled_total_s": modeled,
        "sps_modeled": samples / max(modeled, 1e-9),
        "requests": requests,
        "bytes_read": sum(s.bytes_read for s in per),
        "cache_hits": sum(s.cache_hits for s in per),
        "requests_per_sample": requests / max(samples, 1),
    }


def run_elastic(write_json: bool = True) -> dict:
    generate_tahoe_like(BENCH_DATA_DIR, n_cells=N_CELLS, n_genes=N_GENES,
                        seed=0)
    ref = _reference()
    elastic_got, elastic = _elastic_arm()
    iso_got, isolated = _isolated_arm()

    bitwise = elastic_got == ref and iso_got == ref
    gates = {
        "bitwise_n_m_n": bitwise,
        "shared_rank_hits": elastic["shared_rank_hits"],
        "requests_shared": elastic["requests"],
        "requests_isolated": isolated["requests"],
        "req_per_sample_shared": elastic["requests_per_sample"],
        "req_per_sample_isolated": isolated["requests_per_sample"],
        "bytes_shared": elastic["bytes_read"],
        "bytes_isolated": isolated["bytes_read"],
    }
    passed = (
        bitwise
        and elastic["samples"] == isolated["samples"]
        and elastic["shared_rank_hits"] > 0
        and elastic["requests"] < isolated["requests"]
        and elastic["bytes_read"] < isolated["bytes_read"]
    )
    emit(
        f"elastic_fabric_{WORLD}ranks_shared",
        1e6 / max(elastic["sps_modeled"], 1e-9),
        f"req/sample={elastic['requests_per_sample']:.4f}",
    )
    emit(
        f"elastic_isolated_{WORLD}ranks",
        1e6 / max(isolated["sps_modeled"], 1e-9),
        f"req/sample={isolated['requests_per_sample']:.4f}",
    )
    out = {
        "world_size": WORLD,
        "phase_batches": PHASE_BATCHES,
        "batch_size": BATCH_SIZE,
        "fetch_factor": FETCH_FACTOR,
        "cache_total_bytes": CACHE_TOTAL,
        "epoch_batches": len(ref),
        "elastic": elastic,
        "isolated": isolated,
        "gates": gates,
        "pass": bool(passed),
    }
    if write_json:
        with open(PR10_JSON, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"# wrote {PR10_JSON}")
    return out


def run() -> dict:
    return run_elastic(write_json=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
