"""Multi-tenant serving vs isolated loaders — the shared-cache dividend.

The serve/data claim (docs/serving.md): N tenants training on the same
dataset through ONE :class:`~repro.serve.data.DataServeServer` — one block
cache, one rendezvous table — beat N isolated loader processes, each with
its own collection and a 1/N slice of the same total cache budget, on BOTH
axes:

- **storage work** — a block one tenant faults in is a cache hit (or an
  in-flight rendezvous join) for every other tenant, so total backend GETs
  and bytes read collapse toward the single-tenant cost instead of scaling
  with N;
- **modeled throughput** — samples / (wall + un-slept modeled storage
  time).  Modeled time is the storage device's total work under the
  SATA-SSD model; the device is one and the same in both arms, so summing
  it across isolated loaders is the apples-to-apples comparison.

The tenants run the cloud-profiled fixture (``cloud://`` over the shared
Tahoe-like store, ``latency_scale=0`` — request accounting without real
sleeps) with IDENTICAL specs: the hyperparameter-sweep shape (N replicas of
one data recipe, different model seeds) where the dividend is largest and
any dedup failure is unmissable in the request counters.

``run_serve`` writes machine-readable ``BENCH_PR9.json``; smoke gate #7
(``python -m benchmarks.run --smoke``) exits nonzero unless shared-arm
modeled samples/sec beat the isolated arm by ``SERVE_FLOOR`` AND both
storage-work counters (requests, bytes read) are strictly lower.
"""
from __future__ import annotations

import json
import os
import threading
import time

from benchmarks.common import BENCH_DATA_DIR, N_CELLS, N_GENES, emit
from repro.data import SATA_SSD, IOStats, generate_tahoe_like
from repro.pipeline import Pipeline
from repro.serve.data import DataClient, DataServeServer, ServeConfig

PR9_JSON = os.environ.get("BENCH_PR9_JSON", "BENCH_PR9.json")

N_TENANTS = int(os.environ.get("BENCH_SERVE_TENANTS", "3"))
SERVE_BATCHES = int(os.environ.get("BENCH_SERVE_BATCHES", "48"))
SERVE_FLOOR = 1.2
BATCH_SIZE = 64
#: total block-cache budget, split evenly in the isolated arm
CACHE_TOTAL = 48 << 20


def _spec():
    uri = (
        f"cloud://sharded-csr://{BENCH_DATA_DIR}"
        "?profile=same-region&latency_scale=0"
    )
    # io_workers=2 puts BOTH arms on the async planned path (the server's
    # own default): same executor, same rendezvous machinery — the only
    # variable left is whether the cache/rendezvous plane is shared
    return (
        Pipeline.from_uri(uri, io_workers=2)
        .strategy("block", block_size=16)
        .batch(BATCH_SIZE, fetch_factor=16)
        .seed(0)
        ._spec
    )


def _drain_client(cli: DataClient, counts: list, idx: int) -> None:
    n = 0
    for _ in iter(cli):
        n += 1
        if n >= SERVE_BATCHES:
            break
    counts[idx] = n


def _shared_arm(spec) -> dict:
    stats = IOStats(simulate=SATA_SSD, simulate_scale=0.0)
    srv = DataServeServer(
        ServeConfig(max_tenants=N_TENANTS, cache_bytes=CACHE_TOTAL,
                    queue_depth=2),
        iostats=stats,
    ).start()
    counts = [0] * N_TENANTS
    try:
        clients = [DataClient(srv.address, spec) for _ in range(N_TENANTS)]
        threads = [
            threading.Thread(target=_drain_client, args=(c, counts, i))
            for i, c in enumerate(clients)
        ]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        wall = time.perf_counter() - t0
        for c in clients:
            c.close()
        agg = srv.stats().aggregate
    finally:
        srv.stop()
    samples = sum(counts) * BATCH_SIZE
    modeled = wall + agg["modeled_s"]
    return {
        "samples": samples,
        "wall_s": wall,
        "modeled_total_s": modeled,
        "sps_modeled": samples / max(modeled, 1e-9),
        "requests": agg["requests"],
        "bytes_read": agg["bytes_read"],
        "cache_hits": agg["cache_hits"],
    }


def _drain_local(spec, cache_bytes: int, out: list, idx: int) -> None:
    stats = IOStats(simulate=SATA_SSD, simulate_scale=0.0)
    built = Pipeline(
        spec.replace(cache_bytes=cache_bytes), iostats=stats
    ).build()
    n = 0
    for _ in iter(built):
        n += 1
        if n >= SERVE_BATCHES:
            break
    built.close()
    out[idx] = {
        "batches": n,
        "modeled_s": stats.modeled_s,
        "requests": stats.requests,
        "bytes_read": stats.bytes_read,
        "cache_hits": stats.cache_hits,
    }


def _isolated_arm(spec) -> dict:
    per_tenant_cache = CACHE_TOTAL // N_TENANTS
    results: list = [None] * N_TENANTS
    threads = [
        threading.Thread(
            target=_drain_local, args=(spec, per_tenant_cache, results, i)
        )
        for i in range(N_TENANTS)
    ]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    samples = sum(r["batches"] for r in results) * BATCH_SIZE
    modeled = wall + sum(r["modeled_s"] for r in results)
    return {
        "samples": samples,
        "wall_s": wall,
        "modeled_total_s": modeled,
        "sps_modeled": samples / max(modeled, 1e-9),
        "requests": sum(r["requests"] for r in results),
        "bytes_read": sum(r["bytes_read"] for r in results),
        "cache_hits": sum(r["cache_hits"] for r in results),
    }


def run_serve(write_json: bool = True) -> dict:
    generate_tahoe_like(BENCH_DATA_DIR, n_cells=N_CELLS, n_genes=N_GENES,
                        seed=0)
    spec = _spec()
    shared = _shared_arm(spec)
    isolated = _isolated_arm(spec)

    speedup = shared["sps_modeled"] / max(isolated["sps_modeled"], 1e-9)
    gates = {
        "serve_floor": SERVE_FLOOR,
        "speedup": speedup,
        "requests_shared": shared["requests"],
        "requests_isolated": isolated["requests"],
        "bytes_shared": shared["bytes_read"],
        "bytes_isolated": isolated["bytes_read"],
    }
    passed = (
        shared["samples"] == isolated["samples"]
        and speedup >= SERVE_FLOOR
        and shared["requests"] < isolated["requests"]
        and shared["bytes_read"] < isolated["bytes_read"]
    )
    emit(
        f"serve_shared_{N_TENANTS}tenants",
        1e6 / max(shared["sps_modeled"], 1e-9),
        f"sps_modeled={shared['sps_modeled']:.1f}",
    )
    emit(
        f"serve_isolated_{N_TENANTS}procs",
        1e6 / max(isolated["sps_modeled"], 1e-9),
        f"sps_modeled={isolated['sps_modeled']:.1f}",
    )
    out = {
        "n_tenants": N_TENANTS,
        "batches_per_tenant": SERVE_BATCHES,
        "batch_size": BATCH_SIZE,
        "cache_total_bytes": CACHE_TOTAL,
        "shared": shared,
        "isolated": isolated,
        "gates": gates,
        "pass": bool(passed),
    }
    if write_json:
        with open(PR9_JSON, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"# wrote {PR9_JSON}")
    return out


def run() -> dict:
    return run_serve(write_json=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
