"""Paper Fig. 5 — downstream classification under four loading strategies.

Tasks (linear heads, trained jointly on the same stream): cell_line (50),
drug (380), moa_broad (4), moa_fine (27).  Strategies: Streaming,
Streaming+shuffle-buffer (16,384 = 64x256), BlockShuffling (b=16, f=256),
Random Sampling (b=1).  Train = plates 0..12, test = plate 13 (the paper's
plates 1-13 / 14 split).  2 seeds; metric macro-F1.

Claim under test: streaming variants underperform due to plate-scale
heterogeneity; BlockShuffling b=16,f=256 matches Random Sampling.
Scale adaptations (DESIGN.md §2): 150k synthetic cells (not 94M); lr=1e-2
(paper 1e-5 — lr scales the effective forgetting horizon to the step count);
shuffle buffer scaled to the paper's buffer/plate ratio (16,384 / 7M plate =
0.23% -> 64 cells for our ~11k-cell plates; an UNscaled 16,384 buffer spans
>1 plate here and trivially decorrelates, inverting the geometry the paper
tests).  The *ordering* of strategies is the reproduced result.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit

from repro.core import BlockShuffling, ScDataset, Streaming

M = 64
TASKS = {"cell_line": 50, "drug": 380, "moa_broad": 4, "moa_fine": 27}
SEEDS = (0, 1)
LR = 1e-2


def _strategies():
    return {
        "streaming": (Streaming(), 1),
        # paper buffer/plate ratio: 16384/7e6 * (~11k cells/plate here) ~ 64
        "shuffle_buffer": (Streaming(shuffle_buffer=64), 1),
        "block_shuffling": (BlockShuffling(block_size=16), 256),
        "random_sampling": (BlockShuffling(block_size=1), 256),
    }


def _init_heads(key, n_genes):
    ks = jax.random.split(key, len(TASKS))
    return {
        t: {"w": jnp.zeros((n_genes, c), jnp.float32), "b": jnp.zeros((c,), jnp.float32)}
        for (t, c), k in zip(TASKS.items(), ks)
    }


@jax.jit
def _train_step(heads, opt, x, ys):
    def loss_fn(heads):
        total = 0.0
        for t in TASKS:
            logits = x @ heads[t]["w"] + heads[t]["b"]
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, ys[t][:, None], axis=-1)[:, 0]
            total = total + jnp.mean(lse - gold)
        return total

    loss, grads = jax.value_and_grad(loss_fn)(heads)
    # Adam
    b1, b2, eps = 0.9, 0.999, 1e-8
    cnt = opt["count"] + 1
    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads)
    c1, c2 = 1 - b1 ** cnt.astype(jnp.float32), 1 - b2 ** cnt.astype(jnp.float32)
    heads = jax.tree.map(
        lambda p, m, v: p - LR * (m / c1) / (jnp.sqrt(v / c2) + eps),
        heads, new_m, new_v,
    )
    return heads, {"m": new_m, "v": new_v, "count": cnt}, loss


def _features(batch):
    x = jnp.asarray(batch.to_dense())
    return jnp.log1p(x)


def _macro_f1(pred: np.ndarray, gold: np.ndarray, n_classes: int) -> float:
    f1s = []
    for c in range(n_classes):
        tp = np.sum((pred == c) & (gold == c))
        fp = np.sum((pred == c) & (gold != c))
        fn = np.sum((pred != c) & (gold == c))
        if tp + fp + fn == 0:
            continue  # class absent from test and predictions
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        f1s.append(0.0 if prec + rec == 0 else 2 * prec * rec / (prec + rec))
    return float(np.mean(f1s)) if f1s else 0.0


def run() -> dict:
    store, _ = dataset(simulate_sata=False)
    n_train = sum(len(s) for s in store.shards[:13])
    test_shard = store.shards[13]

    # materialize the (small) test set once
    test_batch = test_shard[np.arange(len(test_shard))]
    x_test = np.log1p(test_batch.to_dense())
    y_test = {t: np.asarray(test_batch.obs[t]) for t in TASKS}

    class TrainView:
        """Restrict the sharded store to the training plates."""

        def __init__(self, store, n):
            self.store, self.n = store, n

        def __len__(self):
            return self.n

        def __getitem__(self, rows):
            return self.store[rows]

    results: dict[str, dict[str, list[float]]] = {
        s: {t: [] for t in TASKS} for s in _strategies()
    }
    for strat_name, (strat, f) in _strategies().items():
        for seed in SEEDS:
            heads = _init_heads(jax.random.PRNGKey(seed), store.n_var)
            opt = {
                "m": jax.tree.map(jnp.zeros_like, heads),
                "v": jax.tree.map(jnp.zeros_like, heads),
                "count": jnp.zeros((), jnp.int32),
            }
            ds = ScDataset(TrainView(store, n_train), strat, batch_size=M,
                           fetch_factor=f, seed=seed)
            t0 = time.time()
            for batch in ds:  # one epoch
                x = _features(batch)
                ys = {t: jnp.asarray(batch.obs[t].astype(np.int32)) for t in TASKS}
                heads, opt, loss = _train_step(heads, opt, x, ys)
            # evaluate
            for t, c in TASKS.items():
                logits = np.asarray(jnp.asarray(x_test) @ heads[t]["w"] + heads[t]["b"])
                pred = logits.argmax(-1)
                results[strat_name][t].append(_macro_f1(pred, y_test[t], c))
            print(f"#  {strat_name} seed {seed}: epoch {time.time()-t0:.0f}s, "
                  f"f1={ {t: round(results[strat_name][t][-1],3) for t in TASKS} }")

    for strat_name, by_task in results.items():
        for t in TASKS:
            arr = np.array(by_task[t])
            emit(f"fig5_{strat_name}_{t}", 0.0,
                 f"macro_f1={arr.mean():.3f}+-{arr.std():.3f}")
    # headline ordering claim
    mean_of = lambda s: np.mean([np.mean(results[s][t]) for t in TASKS])
    emit("fig5_ordering", 0.0,
         f"streaming={mean_of('streaming'):.3f};buffer={mean_of('shuffle_buffer'):.3f};"
         f"block={mean_of('block_shuffling'):.3f};random={mean_of('random_sampling'):.3f};"
         f"claim=block~random>buffer~streaming")
    return results


if __name__ == "__main__":
    run()
