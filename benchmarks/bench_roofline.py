"""Roofline analysis (deliverable g) — three terms per (arch × shape) cell.

Reads results/dryrun/*.json (written by repro.launch.dryrun) and derives,
per cell on the single-pod 16x16 mesh:

  compute_s    = HLO_dot_flops_per_device / 197e12          (bf16 peak)
  memory_s     = HLO_dot_bytes_per_device / 819e9           (HBM BW)
  collective_s = collective_bytes_per_device / 50e9         (ICI link BW)

All three use the loop-corrected HLO costs (launch/hlo_cost.py) since
cost_analysis counts while bodies once.  memory_s uses dot operand+output
bytes as the HBM-traffic proxy (over-counts fusion-resident intermediates,
excludes elementwise traffic — both noted per DESIGN.md §7).

MODEL_FLOPS = 6·N·D (train), 2·N·D (prefill), 2·N_active·B (decode), with
N_active for MoE.  ratio = MODEL_FLOPS / global HLO flops (useful-compute
fraction: remat recompute, padding waste, dispatch overhead all lower it).
roofline_fraction = ideal_compute_s / max(term) — the headline score.
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.mesh import HW  # noqa: E402

RESULTS_GLOB = os.path.join(
    os.path.dirname(__file__), "..", "results", "dryrun", "single__*.json"
)


def model_flops(r: dict) -> float:
    n, na = r["n_params"], r["n_active_params"]
    d = r["tokens_per_step"]
    if r["kind"] == "train":
        return 6.0 * na * d
    if r["kind"] == "prefill":
        return 2.0 * na * d
    return 2.0 * na * d  # decode: d = batch (1 token each)


def min_bytes_floor(r: dict) -> float:
    """Bytes that MUST move through HBM per step, global (ideal lower bound).

    decode: read active params once + read the whole KV/SSM cache once.
    prefill: read params once + write the cache once.
    train: params read (fwd+bwd) + grads written + optimizer state r/w.
    Activations beyond the cache are excluded (they can in principle stay
    on-chip) — this is deliberately an under-estimate so the fraction is
    conservative.
    """
    na, n = r["n_active_params"], r["n_params"]
    cache = r["memory"]["argument_bytes"] * r["chips"]  # donated cache+params args
    if r["kind"] == "decode":
        return 2.0 * na + cache
    if r["kind"] == "prefill":
        return 2.0 * na + cache
    # train: bf16 params x2 reads + bf16 grad write + fp32 m,v read+write
    return 2.0 * n * 2 + 2.0 * n + 4.0 * n * 4


def _note(dom: str, r: dict) -> str:
    if dom == "collective":
        return ("cut TP all-reduce traffic: reshard residual over seq "
                "(SP), overlap with compute, or reduce-scatter grads")
    if dom == "memory":
        return ("cut HBM traffic: larger fused tiles (Pallas), bf16 "
                "optimizer moments, fewer remat re-reads")
    return "compute-bound: raise MFU via fusion/padding cleanup (good place to be)"


def analyze(variant: str = "default") -> list[dict]:
    """Roofline rows for one sweep variant.

    File naming: ``single__{arch}__{shape}.json`` (default sweep) or
    ``single__{arch}__{shape}__{variant}.json``.
    """
    rows = []
    for p in sorted(glob.glob(RESULTS_GLOB)):
        parts = os.path.basename(p)[:-len(".json")].split("__")
        file_variant = parts[3] if len(parts) > 3 else "default"
        if file_variant != variant:
            continue
        r = json.load(open(p))
        chips = r["chips"]
        compute_s = r["flops_per_device"] / HW.PEAK_FLOPS_BF16
        # prefer the TPU-bf16-equivalent bytes when the sweep recorded them
        dot_b = r.get("dot_bytes_eq_per_device", r["dot_bytes_per_device"])
        coll_b = r.get("collective_bytes_eq_per_device",
                       r["collective_bytes_per_device"])
        memory_s = dot_b / HW.HBM_BW
        coll_s = coll_b / HW.ICI_BW
        mf = model_flops(r)
        # ideal step time: the larger of the compute floor and the HBM floor
        ideal_s = max(
            mf / (chips * HW.PEAK_FLOPS_BF16),
            min_bytes_floor(r) / (chips * HW.HBM_BW),
        )
        terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "kind": r["kind"],
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": dom,
            "model_flops": mf,
            "hlo_flops_global": r["flops_per_device"] * chips,
            "useful_ratio": mf / max(r["flops_per_device"] * chips, 1e-9),
            "roofline_fraction": ideal_s / max(bound, 1e-12),
            "peak_mem_gb": r["memory"]["peak_estimate_bytes"] / 1e9,
            "fits_hbm": r["memory"]["peak_estimate_bytes"] <= HW.HBM_BYTES,
            "note": _note(dom, r),
        })
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "MODEL_FLOPS | useful ratio | roofline frac | mem GB (≤16) |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda x: (x["shape"], x["arch"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['model_flops']:.2e} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['peak_mem_gb']:.1f} "
            f"{'✓' if r['fits_hbm'] else '✗'} |"
        )
    return "\n".join(lines)


def run() -> list[dict]:
    from .common import emit

    rows = analyze()
    for r in rows:
        emit(
            f"roofline_{r['arch']}_{r['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.2f};mem={r['peak_mem_gb']:.1f}GB",
        )
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:3]
    emit("roofline_worst3", 0.0,
         ";".join(f"{r['arch']}/{r['shape']}={r['roofline_fraction']:.3f}"
                  for r in worst))
    return rows


if __name__ == "__main__":
    for row in analyze():
        print(row)
