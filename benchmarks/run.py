"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (shared ``emit`` helper) and a
summary.  Individual benches: ``python -m benchmarks.bench_fig2_throughput``.
Environment knobs: BENCH_N_CELLS (default 150000), BENCH_MEASURE_S (1.5),
BENCH_SKIP (comma-list: fig2,fig3,fig4,fig5,table2,roofline,kernels).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    skip = set(filter(None, os.environ.get("BENCH_SKIP", "").split(",")))
    t_all = time.time()
    print("name,us_per_call,derived")

    if "fig2" not in skip:
        from benchmarks import bench_fig2_throughput

        bench_fig2_throughput.run()
    if "fig3" not in skip:
        from benchmarks import bench_fig3_streaming

        bench_fig3_streaming.run()
    if "fig4" not in skip:
        from benchmarks import bench_fig4_entropy

        bench_fig4_entropy.run()
    if "table2" not in skip:
        from benchmarks import bench_table2_multiworker

        bench_table2_multiworker.run()
    if "fig5" not in skip:
        from benchmarks import bench_fig5_classification

        bench_fig5_classification.run()
    if "roofline" not in skip:
        from benchmarks import bench_roofline

        bench_roofline.run()
    if "kernels" not in skip:
        from benchmarks import bench_kernels

        bench_kernels.run()
    if "autotune" not in skip:
        from benchmarks import bench_autotune

        bench_autotune.run()

    print(f"# total bench time: {time.time()-t_all:.0f}s")


if __name__ == "__main__":
    main()
