"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (shared ``emit`` helper) and a
summary.  Individual benches: ``python -m benchmarks.bench_fig2_throughput``.
Environment knobs: BENCH_N_CELLS (default 150000), BENCH_MEASURE_S (1.5),
BENCH_SKIP (comma-list: fig2,fig3,fig4,fig5,table2,roofline,kernels,
autotune,adaptive,resilience,diversity).

``--smoke`` runs ONLY the fast CI gates on a tiny fixture:

1. async-vs-sync planned execution -> machine-readable ``BENCH_PR2.json``
   (samples/sec, runs/sample, cache-hit rate for both modes); exits nonzero
   if async fails to beat sync by ``SMOKE_FLOOR`` (1.5x; the full-fixture
   target is 2x);
2. the cloud request-semantics grid -> ``BENCH_PR3.json`` (per-profile
   fitted per-request cost + recommended (b, f)); exits nonzero unless the
   recommended fetch factor is non-decreasing in first-byte latency and
   strictly larger at the high end (the paper-level claim that bigger
   fetches amortize per-request cost);
3. pipeline parity -> ``BENCH_PR4.json`` (the fig2 cell built through
   ``repro.pipeline`` vs hand-wired ``open_collection`` + ``ScDataset``);
   exits nonzero unless samples/sec agree within 5% AND the IOStats
   counters are identical — the declarative surface must be free glue;
4. the adaptive I/O engine -> ``BENCH_PR5.json`` (weighted sampling over
   the ``cross-region`` cloud fixture, counter-modeled samples/sec): the
   adaptive configuration (TinyLFU admission + readahead="auto" +
   autotuned io_workers) must beat the BEST static (readahead,
   io_workers, admission) cell by ``ADAPTIVE_FLOOR`` (1.3x);
5. self-healing I/O -> ``BENCH_PR7.json`` (flaky cross-region store: ~5%
   transient GET failures + a heavy latency tail, real scaled sleeps):
   the no-retry control arm must FAIL the epoch, retries must hold
   >= 0.7x fault-free wall-clock throughput, and hedged reads must cut
   p95 per-fetch time below 0.9x retry-only's;
6. the diversity observatory -> ``BENCH_PR8.json`` (the Fig. 4
   entropy-vs-throughput frontier measured from the LIVE ``div_*``
   IOStats telemetry): the ``entropy_floor``-autotuned quasi-random
   ``(b, f)`` must land within 0.1 bits of true-random entropy at >= 3x
   its counter-modeled throughput;
7. multi-tenant serving -> ``BENCH_PR9.json`` (N identical tenants
   through ONE shared-cache ``DataServeServer`` vs N isolated loaders
   splitting the same cache budget): shared must beat isolated by
   ``bench_serve.SERVE_FLOOR`` on modeled samples/sec AND issue strictly
   fewer backend requests and bytes (the cross-tenant dedup claim,
   measured from the cloud adapter's request counters);
8. the elastic data fabric -> ``BENCH_PR10.json`` (world 3 → kill a rank
   mid-epoch → resize 2 → resize 3 over ONE shared collection vs the
   same ranks isolated): the kill/resize stream must be BITWISE the
   never-resized epoch, and the shared-collection arm must issue
   strictly fewer cloud requests and bytes per sample (cross-rank read
   dedup, attributed in ``shared_rank_hits``).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SMOKE_FLOOR = 1.5


def smoke() -> int:
    # small fixture + short equal-work drain, set BEFORE benchmarks.common
    # import freezes them; explicit user env still wins.  The fixture must
    # stay larger than the async cells' cache or there is no I/O latency
    # left to overlap and the smoke measures nothing.
    os.environ.setdefault("BENCH_DATA_DIR", "/tmp/repro_bench_smoke")
    os.environ.setdefault("BENCH_N_CELLS", "50000")
    os.environ.setdefault("BENCH_N_GENES", "512")
    os.environ.setdefault("BENCH_ASYNC_BATCHES", "96")
    os.environ.setdefault("BENCH_CLOUD_BATCHES", "16")
    os.environ.setdefault("BENCH_PARITY_BATCHES", "64")
    os.environ.setdefault("BENCH_DIVERSITY_BATCHES", "96")
    print("name,us_per_call,derived")
    from benchmarks import bench_fig2_throughput

    out = bench_fig2_throughput.run_async(write_json=True)
    ok = out["speedup"] >= SMOKE_FLOOR
    print(
        f"# smoke: async {out['speedup']:.2f}x sync "
        f"(floor {SMOKE_FLOOR}x, full-bench target 2x) -> {'OK' if ok else 'FAIL'}"
    )
    cloud = bench_fig2_throughput.run_cloud(write_json=True)
    cok = cloud["fetch_factor_monotone"]
    print(
        f"# smoke: cloud recommended f {cloud['fetch_factors']} over "
        f"rising first-byte latency (must be non-decreasing and grow) "
        f"-> {'OK' if cok else 'FAIL'}"
    )
    parity = bench_fig2_throughput.run_pipeline_parity(write_json=True)
    pok = parity["pass"]
    print(
        f"# smoke: pipeline vs hand-wired {parity['sps_rel_diff']*100:.1f}% "
        f"sps diff (tol 5%), counters identical="
        f"{parity['counters_identical']} -> {'OK' if pok else 'FAIL'}"
    )
    from benchmarks import bench_adaptive

    adaptive = bench_adaptive.run_adaptive(write_json=True)
    aok = adaptive["pass"]
    print(
        f"# smoke: adaptive engine {adaptive['speedup']:.2f}x best static "
        f"({adaptive['best_static']}; floor {bench_adaptive.ADAPTIVE_FLOOR}x) "
        f"-> {'OK' if aok else 'FAIL'}"
    )
    from benchmarks import bench_resilience

    res = bench_resilience.run_resilience(write_json=True)
    rok = res["pass"]
    g = res["gates"]
    print(
        f"# smoke: resilience no_retry_failed={g['no_retry_failed']}, "
        f"retry {g['retry_sps_ratio']:.2f}x fault-free "
        f"(floor {g['retry_floor']}x), hedged p95 "
        f"{g['hedge_p95_ratio']:.2f}x retry-only "
        f"(ceil {g['hedge_p95_fraction']}x) -> {'OK' if rok else 'FAIL'}"
    )
    from benchmarks import bench_diversity

    div = bench_diversity.run_diversity(write_json=True)
    dok = div["pass"]
    print(
        f"# smoke: diversity autotuned (b={div['autotuned']['b']},"
        f"f={div['autotuned']['f']}) gap {div['entropy_gap_bits']:.3f} bits "
        f"(eps {div['epsilon_bits']}) at {div['speedup']:.1f}x random "
        f"(floor {div['throughput_floor']}x) -> {'OK' if dok else 'FAIL'}"
    )
    from benchmarks import bench_serve

    srv = bench_serve.run_serve(write_json=True)
    sok = srv["pass"]
    sg = srv["gates"]
    print(
        f"# smoke: serve shared {sg['speedup']:.2f}x isolated "
        f"(floor {sg['serve_floor']}x), requests "
        f"{sg['requests_shared']} vs {sg['requests_isolated']}, bytes "
        f"{sg['bytes_shared']} vs {sg['bytes_isolated']} "
        f"-> {'OK' if sok else 'FAIL'}"
    )
    from benchmarks import bench_elastic

    ela = bench_elastic.run_elastic(write_json=True)
    eok = ela["pass"]
    eg = ela["gates"]
    print(
        f"# smoke: elastic {ela['elastic']['schedule']} bitwise="
        f"{eg['bitwise_n_m_n']}, req/sample "
        f"{eg['req_per_sample_shared']:.4f} vs "
        f"{eg['req_per_sample_isolated']:.4f} isolated, "
        f"shared_rank_hits={eg['shared_rank_hits']} "
        f"-> {'OK' if eok else 'FAIL'}"
    )
    return 0 if (ok and cok and pok and aok and rok and dok and sok and eok) \
        else 1


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        raise SystemExit(smoke())
    skip = set(filter(None, os.environ.get("BENCH_SKIP", "").split(",")))
    t_all = time.time()
    print("name,us_per_call,derived")

    if "fig2" not in skip:
        from benchmarks import bench_fig2_throughput

        bench_fig2_throughput.run()
    if "fig3" not in skip:
        from benchmarks import bench_fig3_streaming

        bench_fig3_streaming.run()
    if "fig4" not in skip:
        from benchmarks import bench_fig4_entropy

        bench_fig4_entropy.run()
    if "table2" not in skip:
        from benchmarks import bench_table2_multiworker

        bench_table2_multiworker.run()
    if "fig5" not in skip:
        from benchmarks import bench_fig5_classification

        bench_fig5_classification.run()
    if "roofline" not in skip:
        from benchmarks import bench_roofline

        bench_roofline.run()
    if "kernels" not in skip:
        from benchmarks import bench_kernels

        bench_kernels.run()
    if "autotune" not in skip:
        from benchmarks import bench_autotune

        bench_autotune.run()
    if "adaptive" not in skip:
        from benchmarks import bench_adaptive

        bench_adaptive.run()
    if "resilience" not in skip:
        from benchmarks import bench_resilience

        bench_resilience.run()
    if "diversity" not in skip:
        from benchmarks import bench_diversity

        bench_diversity.run()

    print(f"# total bench time: {time.time()-t_all:.0f}s")


if __name__ == "__main__":
    main()
