"""Paper Fig. 2 — data-loading throughput over the (block_size × fetch_factor) grid.

Claim under test: throughput grows with both b and f; at the largest values
scDataset beats the b=1,f=1 random-sampling baseline by >2 orders of
magnitude (204x in the paper on Tahoe-100M/SATA); it plateaus once
b >= m*f (the whole fetch is one contiguous read).
"""
from __future__ import annotations

from benchmarks.common import dataset, emit, timed_samples_per_sec

from repro.core import BlockShuffling, ScDataset

M = 64  # paper's fixed minibatch size
GRID_B = (1, 4, 16, 64, 256, 1024)
GRID_F = (1, 4, 16, 64, 256)


def run() -> dict:
    store, stats = dataset()
    results = {}
    base = None
    for b in GRID_B:
        for f in GRID_F:
            ds = ScDataset(
                store, BlockShuffling(block_size=b), batch_size=M, fetch_factor=f,
                seed=0, batch_transform=lambda bb: bb.to_dense(),
            )
            r = timed_samples_per_sec(iter(ds), stats, batch_size=M)
            results[(b, f)] = r
            if (b, f) == (1, 1):
                base = r
            emit(
                f"fig2_throughput_b{b}_f{f}",
                1e6 / max(r["sps_modeled"], 1e-9),
                f"sps_modeled={r['sps_modeled']:.1f};sps_wall={r['sps_wall']:.0f};"
                f"runs={r['io_runs']}",
            )
    best = max(results.values(), key=lambda r: r["sps_modeled"])
    speedup = best["sps_modeled"] / max(base["sps_modeled"], 1e-9)
    emit("fig2_speedup_best_vs_random", 0.0,
         f"speedup={speedup:.1f}x;baseline_sps={base['sps_modeled']:.1f};"
         f"paper_claim=204x;paper_baseline~20sps")
    return {"results": {f"{b}x{f}": r for (b, f), r in results.items()},
            "speedup": speedup}


if __name__ == "__main__":
    run()
