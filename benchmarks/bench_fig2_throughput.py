"""Paper Fig. 2 — data-loading throughput over the (block_size × fetch_factor) grid.

Claim under test: throughput grows with both b and f; at the largest values
scDataset beats the b=1,f=1 random-sampling baseline by >2 orders of
magnitude (204x in the paper on Tahoe-100M/SATA); it plateaus once
b >= m*f (the whole fetch is one contiguous read).

Each grid cell now runs in TWO modes over the same data:

- ``direct``  — per-backend reads, as the seed benchmark did (the sharded
  CSR store coalesces runs itself, but only within one shard and with no
  memory reuse across fetches);
- ``planned`` — through the unified backend layer (`open_collection`):
  cross-shard run merging + the byte-budgeted LRU block cache, IOStats
  recorded once at the planner level.

The summary row compares total random runs: the planner must touch disk
fewer times than direct reads on the identical index sequence (block-
granular reads merge near-adjacent extents; the cache absorbs refetches).

``run_async`` (PR 2) additionally compares synchronous vs async planned
execution under *slept* per-read storage latency (``simulate_scale > 0``):
identical index sequence, identical delivered batches, but ``io_workers > 1``
overlaps the miss-extent reads and ``readahead`` double-buffers the next
fetch's plan.  Results land in machine-readable ``BENCH_PR2.json``.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import (
    ASYNC_CELL,
    ASYNC_SIM_SCALE,
    async_equal_work,
    dataset,
    emit,
    planned_dataset,
    timed_samples_per_sec,
)

from repro.core import BlockShuffling, ScDataset

M = 64  # paper's fixed minibatch size
GRID_B = (1, 4, 16, 64, 256, 1024)
GRID_F = (1, 4, 16, 64, 256)

ASYNC_WORKERS = int(os.environ.get("BENCH_IO_WORKERS", "4"))
# long enough that the one readahead fetch stranded by the equal-work cut
# (it prefetches past the drain point) is amortized into the noise
ASYNC_BATCHES = int(os.environ.get("BENCH_ASYNC_BATCHES", "384"))
PR2_JSON = os.environ.get("BENCH_PR2_JSON", "BENCH_PR2.json")


def _run_grid(store, stats, mode: str) -> dict:
    results = {}
    for b in GRID_B:
        for f in GRID_F:
            if M * f > len(store):
                emit(f"fig2_{mode}_b{b}_f{f}", 0.0,
                     f"skipped=fetch_size_{M * f}_exceeds_n_{len(store)}")
                continue
            cache = getattr(store, "cache", None)
            if cache is not None:
                cache.clear()  # each cell starts cold
            ds = ScDataset(
                store, BlockShuffling(block_size=b), batch_size=M, fetch_factor=f,
                seed=0, batch_transform=lambda bb: bb.to_dense(),
            )
            r = timed_samples_per_sec(iter(ds), stats, batch_size=M)
            results[(b, f)] = r
            derived = (
                f"sps_modeled={r['sps_modeled']:.1f};sps_wall={r['sps_wall']:.0f};"
                f"runs={r['io_runs']}"
            )
            if mode == "planned":
                derived += (
                    f";bytes={r['bytes_read']};hit_rate={r['cache_hit_rate']:.2f}"
                )
            emit(f"fig2_{mode}_b{b}_f{f}", 1e6 / max(r["sps_modeled"], 1e-9), derived)
    return results


def _async_cell(name: str, *, io_workers: int, readahead: int) -> dict:
    """EQUAL-WORK measurement via the shared comparison cell (common.py)."""
    out = async_equal_work(io_workers=io_workers, readahead=readahead,
                           n_batches=ASYNC_BATCHES, batch_size=M)
    emit(name, 1e6 / max(out["sps_wall"], 1e-9),
         f"sps_wall={out['sps_wall']:.0f};runs_per_sample={out['runs_per_sample']:.4f};"
         f"hit_rate={out['cache_hit_rate']:.2f};io_workers={io_workers};"
         f"readahead={readahead};sim_scale={ASYNC_SIM_SCALE}")
    return out


def run_async(write_json: bool = True) -> dict:
    """Sync vs async planned execution at equal (b, f), slept storage model.

    The delivered batch sequence is identical (same seed, deterministic
    assembly); only the overlap of physical reads differs.  Acceptance bar:
    async >= 2x sync samples/sec under the simulated per-read latency.
    """
    sync = _async_cell("fig2_async_off", io_workers=1, readahead=0)
    asyn = _async_cell("fig2_async_on", io_workers=ASYNC_WORKERS, readahead=1)
    speedup = asyn["sps_wall"] / max(sync["sps_wall"], 1e-9)
    emit("fig2_async_speedup", 0.0,
         f"speedup={speedup:.2f}x;claim=>=2x;io_workers={ASYNC_WORKERS};"
         f"readahead=1;b={ASYNC_CELL['b']};f={ASYNC_CELL['f']};"
         f"sim_scale={ASYNC_SIM_SCALE}")
    out = {
        "bench": "fig2_async_planned_execution",
        "fixture": {**ASYNC_CELL, "batch_size": M, "batches": ASYNC_BATCHES,
                    "sim_scale": ASYNC_SIM_SCALE},
        "sync": sync,
        "async": asyn,
        "speedup": speedup,
        "pass_2x": bool(speedup >= 2.0),
    }
    if write_json:
        with open(PR2_JSON, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"# wrote {PR2_JSON}")
    return out


def run() -> dict:
    store, stats = dataset()
    direct = _run_grid(store, stats, "direct")

    col, pstats = planned_dataset()
    planned = _run_grid(col, pstats, "planned")

    base = direct[(1, 1)]
    best = max(direct.values(), key=lambda r: r["sps_modeled"])
    speedup = best["sps_modeled"] / max(base["sps_modeled"], 1e-9)
    emit("fig2_speedup_best_vs_random", 0.0,
         f"speedup={speedup:.1f}x;baseline_sps={base['sps_modeled']:.1f};"
         f"paper_claim=204x;paper_baseline~20sps")

    # Planner-level IOStats summary: runs (random accesses), bytes, hit rate.
    # Normalize per sample fetched — wall-clock budgets mean the two modes
    # drain different numbers of batches per cell.
    d_runs = sum(r["io_runs"] for r in direct.values())
    d_samp = sum(r["samples"] for r in direct.values())
    p_runs = sum(r["io_runs"] for r in planned.values())
    p_samp = sum(r["samples"] for r in planned.values())
    p_hits = sum(r["cache_hits"] for r in planned.values())
    p_miss = sum(r["cache_misses"] for r in planned.values())
    d_rps = d_runs / max(d_samp, 1)
    p_rps = p_runs / max(p_samp, 1)
    emit(
        "fig2_planner_vs_direct", 0.0,
        f"direct_runs_per_sample={d_rps:.4f};planned_runs_per_sample={p_rps:.4f};"
        f"run_reduction={d_rps / max(p_rps, 1e-12):.1f}x;"
        f"planned_hit_rate={p_hits / max(p_hits + p_miss, 1):.2f};"
        f"planner_fewer_runs={p_rps < d_rps}",
    )

    async_cmp = run_async()

    return {
        "results": {f"{b}x{f}": r for (b, f), r in direct.items()},
        "planned": {f"{b}x{f}": r for (b, f), r in planned.items()},
        "speedup": speedup,
        "direct_runs_per_sample": d_rps,
        "planned_runs_per_sample": p_rps,
        "planner_fewer_runs": bool(p_rps < d_rps),
        "async": async_cmp,
    }


if __name__ == "__main__":
    run()
