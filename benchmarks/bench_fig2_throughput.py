"""Paper Fig. 2 — data-loading throughput over the (block_size × fetch_factor) grid.

Claim under test: throughput grows with both b and f; at the largest values
scDataset beats the b=1,f=1 random-sampling baseline by >2 orders of
magnitude (204x in the paper on Tahoe-100M/SATA); it plateaus once
b >= m*f (the whole fetch is one contiguous read).

Each grid cell now runs in TWO modes over the same data:

- ``direct``  — per-backend reads, as the seed benchmark did (the sharded
  CSR store coalesces runs itself, but only within one shard and with no
  memory reuse across fetches);
- ``planned`` — through the unified backend layer (`open_collection`):
  cross-shard run merging + the byte-budgeted LRU block cache, IOStats
  recorded once at the planner level.

The summary row compares total random runs: the planner must touch disk
fewer times than direct reads on the identical index sequence (block-
granular reads merge near-adjacent extents; the cache absorbs refetches).
"""
from __future__ import annotations

from benchmarks.common import dataset, emit, planned_dataset, timed_samples_per_sec

from repro.core import BlockShuffling, ScDataset

M = 64  # paper's fixed minibatch size
GRID_B = (1, 4, 16, 64, 256, 1024)
GRID_F = (1, 4, 16, 64, 256)


def _run_grid(store, stats, mode: str) -> dict:
    results = {}
    for b in GRID_B:
        for f in GRID_F:
            if M * f > len(store):
                emit(f"fig2_{mode}_b{b}_f{f}", 0.0,
                     f"skipped=fetch_size_{M * f}_exceeds_n_{len(store)}")
                continue
            cache = getattr(store, "cache", None)
            if cache is not None:
                cache.clear()  # each cell starts cold
            ds = ScDataset(
                store, BlockShuffling(block_size=b), batch_size=M, fetch_factor=f,
                seed=0, batch_transform=lambda bb: bb.to_dense(),
            )
            r = timed_samples_per_sec(iter(ds), stats, batch_size=M)
            results[(b, f)] = r
            derived = (
                f"sps_modeled={r['sps_modeled']:.1f};sps_wall={r['sps_wall']:.0f};"
                f"runs={r['io_runs']}"
            )
            if mode == "planned":
                derived += (
                    f";bytes={r['bytes_read']};hit_rate={r['cache_hit_rate']:.2f}"
                )
            emit(f"fig2_{mode}_b{b}_f{f}", 1e6 / max(r["sps_modeled"], 1e-9), derived)
    return results


def run() -> dict:
    store, stats = dataset()
    direct = _run_grid(store, stats, "direct")

    col, pstats = planned_dataset()
    planned = _run_grid(col, pstats, "planned")

    base = direct[(1, 1)]
    best = max(direct.values(), key=lambda r: r["sps_modeled"])
    speedup = best["sps_modeled"] / max(base["sps_modeled"], 1e-9)
    emit("fig2_speedup_best_vs_random", 0.0,
         f"speedup={speedup:.1f}x;baseline_sps={base['sps_modeled']:.1f};"
         f"paper_claim=204x;paper_baseline~20sps")

    # Planner-level IOStats summary: runs (random accesses), bytes, hit rate.
    # Normalize per sample fetched — wall-clock budgets mean the two modes
    # drain different numbers of batches per cell.
    d_runs = sum(r["io_runs"] for r in direct.values())
    d_samp = sum(r["samples"] for r in direct.values())
    p_runs = sum(r["io_runs"] for r in planned.values())
    p_samp = sum(r["samples"] for r in planned.values())
    p_hits = sum(r["cache_hits"] for r in planned.values())
    p_miss = sum(r["cache_misses"] for r in planned.values())
    d_rps = d_runs / max(d_samp, 1)
    p_rps = p_runs / max(p_samp, 1)
    emit(
        "fig2_planner_vs_direct", 0.0,
        f"direct_runs_per_sample={d_rps:.4f};planned_runs_per_sample={p_rps:.4f};"
        f"run_reduction={d_rps / max(p_rps, 1e-12):.1f}x;"
        f"planned_hit_rate={p_hits / max(p_hits + p_miss, 1):.2f};"
        f"planner_fewer_runs={p_rps < d_rps}",
    )
    return {
        "results": {f"{b}x{f}": r for (b, f), r in direct.items()},
        "planned": {f"{b}x{f}": r for (b, f), r in planned.items()},
        "speedup": speedup,
        "direct_runs_per_sample": d_rps,
        "planned_runs_per_sample": p_rps,
        "planner_fewer_runs": bool(p_rps < d_rps),
    }


if __name__ == "__main__":
    run()
