"""Paper Fig. 2 — data-loading throughput over the (block_size × fetch_factor) grid.

Claim under test: throughput grows with both b and f; at the largest values
scDataset beats the b=1,f=1 random-sampling baseline by >2 orders of
magnitude (204x in the paper on Tahoe-100M/SATA); it plateaus once
b >= m*f (the whole fetch is one contiguous read).

Each grid cell now runs in TWO modes over the same data:

- ``direct``  — per-backend reads, as the seed benchmark did (the sharded
  CSR store coalesces runs itself, but only within one shard and with no
  memory reuse across fetches);
- ``planned`` — through the unified backend layer (`open_collection`):
  cross-shard run merging + the byte-budgeted LRU block cache, IOStats
  recorded once at the planner level.

The summary row compares total random runs: the planner must touch disk
fewer times than direct reads on the identical index sequence (block-
granular reads merge near-adjacent extents; the cache absorbs refetches).

``run_async`` (PR 2) additionally compares synchronous vs async planned
execution under *slept* per-read storage latency (``simulate_scale > 0``):
identical index sequence, identical delivered batches, but ``io_workers > 1``
overlaps the miss-extent reads and ``readahead`` double-buffers the next
fetch's plan.  Results land in machine-readable ``BENCH_PR2.json``.

``run_cloud`` (PR 3) re-runs the grid question under object-store REQUEST
semantics: the same fixture behind ``cloud://`` (every planner extent is one
simulated GET with first-byte latency, bandwidth, and an in-flight cap), one
column per :data:`repro.data.CLOUD_PROFILES` tier.  Per profile it fits the
planner-level cost model (``probe_collection`` — ``c_seek`` is the fitted
per-request cost), sweeps the modeled (b, f) grid, measures one equal-work
cell, and asks ``recommend`` (with ``throughput_slack``) for the leanest
near-optimal configuration.  Claim under test: the recommended fetch factor
grows monotonically with first-byte latency — big fetches amortize
per-request cost, so the pricier each GET, the more rows one should fetch
per call.  Results land in machine-readable ``BENCH_PR3.json``.

``run_pipeline_parity`` (PR 4) guards the declarative surface: the shared
comparison cell built through ``repro.pipeline`` must match the hand-wired
``open_collection`` + ``ScDataset`` construction — samples/sec within 5% and
bit-identical IOStats counters (``BENCH_PR4.json``; the third ``--smoke``
gate).  All grid cells construct through the Pipeline API.
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import (
    ASYNC_CELL,
    ASYNC_SIM_SCALE,
    async_cell_pipeline,
    async_equal_work,
    cloud_collection,
    dataset,
    drain,
    emit,
    planned_dataset,
    timed_samples_per_sec,
)

from repro.core import BlockShuffling, ScDataset
from repro.pipeline import Pipeline

M = 64  # paper's fixed minibatch size
GRID_B = (1, 4, 16, 64, 256, 1024)
GRID_F = (1, 4, 16, 64, 256)

ASYNC_WORKERS = int(os.environ.get("BENCH_IO_WORKERS", "4"))
# long enough that the one readahead fetch stranded by the equal-work cut
# (it prefetches past the drain point) is amortized into the noise
ASYNC_BATCHES = int(os.environ.get("BENCH_ASYNC_BATCHES", "384"))
PR2_JSON = os.environ.get("BENCH_PR2_JSON", "BENCH_PR2.json")

# ---- pipeline parity (PR 4): the Pipeline API must be free glue ---------
PR4_JSON = os.environ.get("BENCH_PR4_JSON", "BENCH_PR4.json")
PARITY_BATCHES = int(os.environ.get("BENCH_PARITY_BATCHES", "96"))
# samples/sec tolerance, on the repo's standard MODELED time base (wall +
# un-slept storage model; see benchmarks/common.py): slept+modeled latency
# is identical by construction (counters are), so the modeled basis damps
# host scheduler noise while still exposing real added CPU in the glue —
# wall is ~20% of the denominator, so e.g. +50% CPU overhead breaks 5%.
PARITY_SPS_TOL = 0.05
# Counters that must be IDENTICAL between the two constructions: same index
# sequence + cold cache + synchronous execution => the planner does exactly
# the same physical work regardless of which surface wired it.
PARITY_COUNTERS = (
    "calls", "runs", "rows", "bytes_read", "cache_hits", "cache_misses",
    "prefetched",
)

# ---- cloud grid (PR 3): profiles ordered by first-byte latency ----------
CLOUD_GRID_PROFILES = ("local-ssd", "same-region", "cross-region", "cold-archive")
CLOUD_SCALE = float(os.environ.get("BENCH_CLOUD_SCALE", "0.25"))
CLOUD_MEASURE_BATCHES = int(os.environ.get("BENCH_CLOUD_BATCHES", "32"))
# "within 10% of the modeled best, smallest buffer wins": tight enough that
# high-latency tiers cannot hide a 15-25% seek-amortization gain inside the
# window (they must recommend the bigger f), loose enough that cheap tiers
# are not forced to the memory cap by sub-noise gains
CLOUD_THROUGHPUT_SLACK = 0.1
PR3_JSON = os.environ.get("BENCH_PR3_JSON", "BENCH_PR3.json")


def _run_grid(store, stats, mode: str) -> dict:
    results = {}
    for b in GRID_B:
        for f in GRID_F:
            if M * f > len(store):
                emit(f"fig2_{mode}_b{b}_f{f}", 0.0,
                     f"skipped=fetch_size_{M * f}_exceeds_n_{len(store)}")
                continue
            cache = getattr(store, "cache", None)
            if cache is not None:
                cache.clear()  # each cell starts cold
            ds = (
                Pipeline.from_collection(store)
                .strategy("block", block_size=b)
                .batch(M, fetch_factor=f)
                .seed(0)
                .build(batch_transform=lambda bb: bb.to_dense())
            )
            r = timed_samples_per_sec(iter(ds), stats, batch_size=M)
            results[(b, f)] = r
            derived = (
                f"sps_modeled={r['sps_modeled']:.1f};sps_wall={r['sps_wall']:.0f};"
                f"runs={r['io_runs']}"
            )
            if mode == "planned":
                derived += (
                    f";bytes={r['bytes_read']};hit_rate={r['cache_hit_rate']:.2f}"
                )
            emit(f"fig2_{mode}_b{b}_f{f}", 1e6 / max(r["sps_modeled"], 1e-9), derived)
    return results


def _async_cell(name: str, *, io_workers: int, readahead: int) -> dict:
    """EQUAL-WORK measurement via the shared comparison cell (common.py)."""
    out = async_equal_work(io_workers=io_workers, readahead=readahead,
                           n_batches=ASYNC_BATCHES, batch_size=M)
    emit(name, 1e6 / max(out["sps_wall"], 1e-9),
         f"sps_wall={out['sps_wall']:.0f};runs_per_sample={out['runs_per_sample']:.4f};"
         f"hit_rate={out['cache_hit_rate']:.2f};io_workers={io_workers};"
         f"readahead={readahead};sim_scale={ASYNC_SIM_SCALE}")
    return out


def run_async(write_json: bool = True) -> dict:
    """Sync vs async planned execution at equal (b, f), slept storage model.

    The delivered batch sequence is identical (same seed, deterministic
    assembly); only the overlap of physical reads differs.  Acceptance bar:
    async >= 2x sync samples/sec under the simulated per-read latency.
    """
    sync = _async_cell("fig2_async_off", io_workers=1, readahead=0)
    asyn = _async_cell("fig2_async_on", io_workers=ASYNC_WORKERS, readahead=1)
    speedup = asyn["sps_wall"] / max(sync["sps_wall"], 1e-9)
    emit("fig2_async_speedup", 0.0,
         f"speedup={speedup:.2f}x;claim=>=2x;io_workers={ASYNC_WORKERS};"
         f"readahead=1;b={ASYNC_CELL['b']};f={ASYNC_CELL['f']};"
         f"sim_scale={ASYNC_SIM_SCALE}")
    out = {
        "bench": "fig2_async_planned_execution",
        "fixture": {**ASYNC_CELL, "batch_size": M, "batches": ASYNC_BATCHES,
                    "sim_scale": ASYNC_SIM_SCALE},
        "sync": sync,
        "async": asyn,
        "speedup": speedup,
        "pass_2x": bool(speedup >= 2.0),
    }
    if write_json:
        with open(PR2_JSON, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"# wrote {PR2_JSON}")
    return out


def run_pipeline_parity(write_json: bool = True) -> dict:
    """PR 4 gate: Pipeline-built vs hand-wired fig2 cell, equal work.

    The declarative surface (``repro.pipeline``) must be pure wiring: the
    shared comparison cell constructed by hand (``open_collection`` +
    ``ScDataset``) and through ``Pipeline.from_uri(...).build()`` runs the
    identical index sequence over a cold planner, so samples/sec must agree
    within ``PARITY_SPS_TOL`` (slept storage latency dominates, so the
    tolerance is real headroom, not noise) and the IOStats counters must be
    IDENTICAL — any divergence means the glue changed the stream or the I/O.
    Synchronous execution (io_workers=1, readahead=0) so counters are
    deterministic.  Each side runs twice in ALTERNATING order and reports
    its best drain — the slept storage latency is identical by construction,
    so what remains is one-sided scheduler/page-cache noise, which
    best-of-two on both sides cancels instead of failing the gate.
    Results land in machine-readable ``BENCH_PR4.json``.
    """

    def hand_wired() -> tuple[dict, dict]:
        # the PR 1-3 surface, knob for knob the same cell
        col, stats = planned_dataset(
            simulate_scale=ASYNC_SIM_SCALE, io_workers=1, readahead=0,
            cache_bytes=ASYNC_CELL["cache_bytes"],
            block_rows=ASYNC_CELL["block_rows"],
        )
        ds = ScDataset(
            col, BlockShuffling(block_size=ASYNC_CELL["b"]), batch_size=M,
            fetch_factor=ASYNC_CELL["f"], seed=0,
            batch_transform=lambda bb: bb.to_dense(),
        )
        out = drain(iter(ds), stats, n_batches=PARITY_BATCHES, batch_size=M)
        snap = stats.snapshot()
        col.release()
        return out, {k: snap[k] for k in PARITY_COUNTERS}

    def declared() -> tuple[dict, dict]:
        # one Pipeline chain carrying the same knobs
        pipe, pstats = async_cell_pipeline(io_workers=1, readahead=0,
                                           batch_size=M)
        out = drain(iter(pipe), pstats, n_batches=PARITY_BATCHES, batch_size=M)
        psnap = pstats.snapshot()
        pipe.close()
        return out, {k: psnap[k] for k in PARITY_COUNTERS}

    reps = []
    for rep in (0, 1):
        sides = (hand_wired, declared) if rep == 0 else (declared, hand_wired)
        got = {fn.__name__: fn() for fn in sides}
        reps.append(got)
    hand, hand_counters = max(
        (r["hand_wired"] for r in reps), key=lambda hc: hc[0]["sps_modeled"]
    )
    piped, pipe_counters = max(
        (r["declared"] for r in reps), key=lambda hc: hc[0]["sps_modeled"]
    )
    # counters must be identical across sides AND reps (determinism)
    all_counters = [c for r in reps for _, c in r.values()]
    counters_all_equal = all(c == all_counters[0] for c in all_counters)

    rel = abs(piped["sps_modeled"] - hand["sps_modeled"]) / max(
        hand["sps_modeled"], 1e-9
    )
    counters_identical = counters_all_equal and hand_counters == pipe_counters
    ok = counters_identical and rel <= PARITY_SPS_TOL
    emit("fig2_pipeline_parity", 1e6 / max(piped["sps_modeled"], 1e-9),
         f"handwired_sps={hand['sps_modeled']:.0f};"
         f"pipeline_sps={piped['sps_modeled']:.0f};"
         f"rel_diff={rel:.3f};tol={PARITY_SPS_TOL};"
         f"counters_identical={counters_identical};pass={ok}")
    out = {
        "bench": "fig2_pipeline_parity",
        "fixture": {**ASYNC_CELL, "batch_size": M, "batches": PARITY_BATCHES,
                    "sim_scale": ASYNC_SIM_SCALE},
        "handwired": {**hand, "counters": hand_counters},
        "pipeline": {**piped, "counters": pipe_counters},
        "sps_rel_diff": rel,
        "sps_tolerance": PARITY_SPS_TOL,
        "counters_identical": counters_identical,
        "pass": ok,
    }
    if write_json:
        with open(PR4_JSON, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"# wrote {PR4_JSON}")
    return out


def _cloud_measured_cell(name: str) -> dict:
    """ONE measured (not modeled) cell per profile: drain a few batches with
    ``io_workers`` overlapping the simulated GETs; requests/sample is the
    request-semantics analogue of fig2's runs/sample."""
    import time

    col, stats = cloud_collection(
        name, latency_scale=CLOUD_SCALE, io_workers=ASYNC_WORKERS
    )
    ds = (
        Pipeline.from_collection(col)
        .strategy("block", block_size=ASYNC_CELL["b"])
        .batch(M, fetch_factor=16)
        .seed(0)
        .build(batch_transform=lambda bb: bb.to_dense())
    )
    n = 0
    t0 = time.perf_counter()
    for _ in iter(ds):
        n += 1
        if n >= CLOUD_MEASURE_BATCHES:
            break
    wall = time.perf_counter() - t0
    col.close()
    return {
        "samples": n * M,
        "sps_wall": n * M / max(wall, 1e-9),
        "requests": stats.requests,
        "requests_per_sample": stats.requests / max(1, stats.rows),
        "request_wait_s": stats.request_wait_s,
    }


def run_cloud(write_json: bool = True) -> dict:
    """Fig. 2 under request semantics, one column per cloud profile.

    Per profile: fit the cost model through the planner (``c_seek`` == fitted
    per-request cost), model the (b, f) grid, measure one cell, and take the
    ``recommend`` pick.  Acceptance: recommended f non-decreasing in
    first-byte latency, strictly larger at the high end than the low end.
    """
    from repro.core.autotune import probe_collection, recommend
    from repro.data import CLOUD_PROFILES

    profiles = []
    for name in CLOUD_GRID_PROFILES:
        prof = CLOUD_PROFILES[name]
        col, stats = cloud_collection(name, latency_scale=CLOUD_SCALE)
        model = probe_collection(col, probes=3, probe_rows=512)
        model.row_bytes = 50_000  # Tahoe-scale sparse rows for the budget
        # One-sided timing noise on a loaded runner can fit a cheap tier's
        # per-request cost above a pricier tier's.  The injected first-byte
        # latency is a hard physical floor per GET (it is slept on every
        # request), so anchor the fit there; fits above the floor are kept.
        model.c_seek = max(model.c_seek, prof.first_byte_s * CLOUD_SCALE)
        rec = recommend(model, batch_size=M, num_classes=14,
                        mem_budget_bytes=2e9, entropy_slack_bits=0.1,
                        throughput_slack=CLOUD_THROUGHPUT_SLACK)
        grid = {
            f"{b}x{f}": model.samples_per_sec(M, f, b)
            for b in GRID_B for f in GRID_F
        }
        measured = _cloud_measured_cell(name)
        emit(f"fig2_cloud_{name}", 1e6 / max(measured["sps_wall"], 1e-9),
             f"first_byte_ms={prof.first_byte_s * 1e3:.1f};"
             f"c_seek_ms={model.c_seek * 1e3:.2f};"
             f"req_per_sample={measured['requests_per_sample']:.4f};"
             f"rec_b={rec.block_size};rec_f={rec.fetch_factor};"
             f"sps_wall={measured['sps_wall']:.0f};scale={CLOUD_SCALE}")
        profiles.append({
            "profile": name,
            "first_byte_s": prof.first_byte_s,
            "bw_Bps": prof.bw_Bps,
            "max_inflight": prof.max_inflight,
            "fitted": {"c0": model.c0, "c_seek": model.c_seek,
                       "c_byte": model.c_byte,
                       "requests_per_sample": model.requests_per_sample},
            "recommended": {"b": rec.block_size, "f": rec.fetch_factor,
                            "modeled_sps": rec.modeled_samples_per_sec},
            "measured_cell": measured,
            "modeled_sps_grid": grid,
        })
    fs = [p["recommended"]["f"] for p in profiles]
    monotone = all(a <= b for a, b in zip(fs, fs[1:])) and fs[-1] > fs[0]
    emit("fig2_cloud_f_monotone", 0.0,
         f"fetch_factors={fs};monotone_nondecreasing_and_growing={monotone};"
         f"claim=f_grows_with_first_byte_latency")
    out = {
        "bench": "fig2_cloud_request_semantics",
        "fixture": {"scale": CLOUD_SCALE, "batch_size": M,
                    "throughput_slack": CLOUD_THROUGHPUT_SLACK,
                    "profiles": list(CLOUD_GRID_PROFILES)},
        "profiles": profiles,
        "fetch_factors": fs,
        "fetch_factor_monotone": bool(monotone),
    }
    if write_json:
        with open(PR3_JSON, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"# wrote {PR3_JSON}")
    return out


def run() -> dict:
    store, stats = dataset()
    direct = _run_grid(store, stats, "direct")

    col, pstats = planned_dataset()
    planned = _run_grid(col, pstats, "planned")

    base = direct[(1, 1)]
    best = max(direct.values(), key=lambda r: r["sps_modeled"])
    speedup = best["sps_modeled"] / max(base["sps_modeled"], 1e-9)
    emit("fig2_speedup_best_vs_random", 0.0,
         f"speedup={speedup:.1f}x;baseline_sps={base['sps_modeled']:.1f};"
         f"paper_claim=204x;paper_baseline~20sps")

    # Planner-level IOStats summary: runs (random accesses), bytes, hit rate.
    # Normalize per sample fetched — wall-clock budgets mean the two modes
    # drain different numbers of batches per cell.
    d_runs = sum(r["io_runs"] for r in direct.values())
    d_samp = sum(r["samples"] for r in direct.values())
    p_runs = sum(r["io_runs"] for r in planned.values())
    p_samp = sum(r["samples"] for r in planned.values())
    p_hits = sum(r["cache_hits"] for r in planned.values())
    p_miss = sum(r["cache_misses"] for r in planned.values())
    d_rps = d_runs / max(d_samp, 1)
    p_rps = p_runs / max(p_samp, 1)
    emit(
        "fig2_planner_vs_direct", 0.0,
        f"direct_runs_per_sample={d_rps:.4f};planned_runs_per_sample={p_rps:.4f};"
        f"run_reduction={d_rps / max(p_rps, 1e-12):.1f}x;"
        f"planned_hit_rate={p_hits / max(p_hits + p_miss, 1):.2f};"
        f"planner_fewer_runs={p_rps < d_rps}",
    )

    async_cmp = run_async()
    cloud_cmp = run_cloud()
    parity = run_pipeline_parity()

    return {
        "results": {f"{b}x{f}": r for (b, f), r in direct.items()},
        "planned": {f"{b}x{f}": r for (b, f), r in planned.items()},
        "speedup": speedup,
        "direct_runs_per_sample": d_rps,
        "planned_runs_per_sample": p_rps,
        "planner_fewer_runs": bool(p_rps < d_rps),
        "async": async_cmp,
        "cloud": cloud_cmp,
        "pipeline_parity": parity,
    }


def _cli() -> None:
    ap = argparse.ArgumentParser(
        description=(
            "Paper Fig. 2: data-loading throughput over the (block_size x "
            "fetch_factor) grid.  Modes: the full grid runs every cell twice "
            "(direct per-backend reads vs the planned unified layer with "
            "cross-shard coalescing + block cache), then the async "
            "sync-vs-async comparison (BENCH_PR2.json) and the cloud "
            "request-semantics grid over CloudProfiles (BENCH_PR3.json)."
        ),
        epilog=(
            "Env knobs: BENCH_N_CELLS, BENCH_MEASURE_S, BENCH_IO_WORKERS, "
            "BENCH_ASYNC_BATCHES, BENCH_SIM_SCALE, BENCH_CLOUD_SCALE, "
            "BENCH_CLOUD_BATCHES, BENCH_PR2_JSON, BENCH_PR3_JSON."
        ),
    )
    ap.add_argument("--async-only", action="store_true",
                    help="only the sync-vs-async planned comparison (BENCH_PR2.json)")
    ap.add_argument("--cloud-only", action="store_true",
                    help="only the cloud-profile request-semantics grid (BENCH_PR3.json)")
    ap.add_argument("--parity-only", action="store_true",
                    help="only the Pipeline-vs-handwired parity cell (BENCH_PR4.json)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.async_only:
        run_async()
    elif args.cloud_only:
        run_cloud()
    elif args.parity_only:
        run_pipeline_parity()
    else:
        run()


if __name__ == "__main__":
    _cli()
