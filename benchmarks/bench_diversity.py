"""PR 8 — the diversity observatory: entropy-vs-throughput frontier (Fig. 4).

Claim under test (paper §3.4 / Fig. 4, the headline trade-off): block
sampling with a large enough fetch factor matches TRUE-RANDOM minibatch
diversity at a fraction of the I/O — quasi-random `(b, f)` reaches the
random-sampling entropy plateau while reading blocks instead of rows.

This bench makes the claim enforceable end to end through the PR 8 stack:

- every frontier cell is built through the Pipeline surface with
  ``.diversity(obs="plate")``, so the measured entropy IS the live
  ``div_*`` IOStats telemetry (no offline label collection);
- the quasi-random cell is not hand-picked: ``recommend(...,
  entropy_floor=...)`` chooses it from the §3.4 bias expansion — the gate
  therefore also covers the entropy-floor autotune path;
- throughput is MODELED from the measured runs/bytes counters under the
  SATA-SSD/HDF5 storage model (``t = seek_s * runs + bytes / bw``) —
  deterministic, like every other smoke gate.

``run_diversity`` writes machine-readable ``BENCH_PR8.json``; smoke gate #6
(``benchmarks/run.py --smoke``) fails CI unless the floor-autotuned cell
stays within ``EPSILON_BITS`` of true-random entropy at
``THROUGHPUT_FLOOR``x its modeled throughput.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import BENCH_DATA_DIR, N_CELLS, N_GENES, emit

from repro.core.autotune import IOCostModel, recommend
from repro.core.theory import distribution_entropy
from repro.data import SATA_SSD, IOStats
from repro.data.synth import generate_tahoe_like
from repro.pipeline import Pipeline

PR8_JSON = os.environ.get("BENCH_PR8_JSON", "BENCH_PR8.json")
EPSILON_BITS = 0.1  # quasi must land within this of true-random entropy
THROUGHPUT_FLOOR = 3.0  # ... at >= this x true-random modeled throughput

M = 64
# frontier grid: b capped at m (beyond it whole batches collapse to one
# plate and no f in the grid recovers — fig4 covers that regime)
GRID_B = (1, 4, 16, 64)
GRID_F = (1, 4, 16, 64, 256)
N_BATCHES = int(os.environ.get("BENCH_DIVERSITY_BATCHES", "96"))


def _measure_cell(b: int, f: int) -> dict:
    """Drain cell (b, f) cold-cache and report live-telemetry entropy +
    counter-modeled throughput.

    ``cache_bytes=0`` so runs/bytes reflect raw planned I/O (the regime the
    Fig. 4 trade-off is about), and the drain is a FULL-FETCH multiple of
    ``N_BATCHES`` so the ``div_*`` counters cover exactly the delivered
    batches (``fetch`` materializes — and the monitor observes — all f
    minibatches at once).
    """
    stats = IOStats()
    pipe = (
        Pipeline.from_uri(
            "sharded-csr://" + BENCH_DATA_DIR, cache_bytes=0, iostats=stats
        )
        .strategy("block", block_size=b)
        .batch(M, fetch_factor=f)
        .seed(0)
        .diversity(obs="plate")
        .build()
    )
    n_target = -(-N_BATCHES // f) * f  # ceil to a fetch boundary
    n = 0
    for _ in iter(pipe):
        n += 1
        if n >= n_target:
            break
    pipe.close()
    snap = stats.snapshot()
    assert snap["div_batches"] == n, (
        f"diversity counters saw {snap['div_batches']} batches, delivered {n}"
    )
    samples = n * M
    t = SATA_SSD.seek_s * snap["runs"] + snap["bytes_read"] / SATA_SSD.bw_Bps
    return {
        "b": b,
        "f": f,
        "batches": n,
        "entropy_mean": snap["div_entropy_sum"] / snap["div_batches"],
        "entropy_min": snap["div_entropy_min"],
        "sps_modeled": samples / max(t, 1e-12),
        "runs_per_sample": snap["runs"] / max(1, snap["rows"]),
        "bytes_read": snap["bytes_read"],
    }


def run_diversity(write_json: bool = True) -> dict:
    generate_tahoe_like(BENCH_DATA_DIR, n_cells=N_CELLS, n_genes=N_GENES,
                        seed=0)
    # the class distribution the floor is set against, via the same obs
    # column the monitors observe
    probe = Pipeline.from_uri("sharded-csr://" + BENCH_DATA_DIR)._open()
    plate = np.asarray(probe.obs_column("plate"))
    _, counts = np.unique(plate, return_counts=True)
    p = counts / counts.sum()
    row_bytes = float(probe.avg_row_bytes)
    n_rows = float(len(probe))
    probe.release()
    Hp = distribution_entropy(p)
    K = int(len(p))
    iid_deficit = (K - 1) / (2.0 * M * np.log(2.0))

    # ---- the frontier: live-telemetry entropy x counter-modeled throughput
    frontier = []
    for b in GRID_B:
        for f in GRID_F:
            cell = _measure_cell(b, f)
            frontier.append(cell)
            emit(
                f"diversity_frontier_b{b}_f{f}",
                1e6 / max(cell["sps_modeled"], 1e-9),
                f"H={cell['entropy_mean']:.3f};Hmin={cell['entropy_min']:.2f};"
                f"sps_modeled={cell['sps_modeled']:.1f};"
                f"runs_per_sample={cell['runs_per_sample']:.4f}",
            )

    # ---- the entropy-floor autotune picks the quasi-random cell.  The
    # analytic SATA model mirrors the throughput model above (c0=0: no
    # per-call overhead in the counter-modeled time base), so "max modeled
    # sps subject to predicted E[H] >= floor" selects on the same frontier
    # the gate measures.  Floor: within a twentieth of a bit of the best
    # E[H] ANY m=64 sampler can reach (Thm 3.1) — an absolute SLO, not a
    # hand-picked (b, f).
    floor = Hp - iid_deficit - 0.05
    cost = IOCostModel(
        c0=0.0, c_seek=SATA_SSD.seek_s, c_byte=1.0 / SATA_SSD.bw_Bps,
        row_bytes=row_bytes, n_rows=n_rows,
    )
    rec = recommend(
        cost, batch_size=M, class_probs=p, entropy_floor=floor,
        b_grid=GRID_B, f_grid=GRID_F,
    )
    emit("diversity_autotune_pick", 0.0,
         f"b={rec.block_size};f={rec.fetch_factor};"
         f"predicted_H={rec.predicted_entropy:.3f};floor={floor:.3f}")

    by_cell = {(c["b"], c["f"]): c for c in frontier}
    quasi = by_cell[(rec.block_size, rec.fetch_factor)]
    random_cell = by_cell[(1, 1)]  # true-random: every row drawn independently

    gap = random_cell["entropy_mean"] - quasi["entropy_mean"]
    speedup = quasi["sps_modeled"] / max(random_cell["sps_modeled"], 1e-9)
    ok_entropy = gap <= EPSILON_BITS
    ok_speed = speedup >= THROUGHPUT_FLOOR
    ok = ok_entropy and ok_speed
    emit(
        "diversity_gate", 0.0,
        f"gap_bits={gap:.3f};eps={EPSILON_BITS};speedup={speedup:.1f}x;"
        f"floor={THROUGHPUT_FLOOR}x;pass={ok}",
    )

    out = {
        "bench": "diversity_observatory",
        "fixture": {
            "n_cells": int(n_rows),
            "batch_size": M,
            "n_batches": N_BATCHES,
            "plates": K,
            "Hp": Hp,
            "iid_deficit": iid_deficit,
        },
        "frontier": [
            {**c, "cell": f"b{c['b']}_f{c['f']}"} for c in frontier
        ],
        "entropy_floor": floor,
        "autotuned": {
            "b": rec.block_size,
            "f": rec.fetch_factor,
            "predicted_entropy": rec.predicted_entropy,
            "rationale": rec.rationale,
        },
        "quasi": quasi,
        "random": random_cell,
        "entropy_gap_bits": gap,
        "epsilon_bits": EPSILON_BITS,
        "speedup": speedup,
        "throughput_floor": THROUGHPUT_FLOOR,
        "pass": bool(ok),
    }
    if write_json:
        with open(PR8_JSON, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"# wrote {PR8_JSON}")
    return out


def run() -> dict:
    return run_diversity(write_json=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
