"""PR 7 — self-healing I/O on a flaky cross-region store.

Claim under test: on object storage that actually misbehaves (~5% of GETs
fail transiently, ~10% land in a heavy latency tail), the resilience
machinery keeps the pipeline BOTH alive and fast:

- **no_retry** (the control arm): the same fault stream with resilience off
  must kill the epoch — if it survives, the fixture is not flaky enough to
  gate anything;
- **retry_only**: bounded retries + decorrelated-jitter backoff deliver the
  complete stream at >= ``RETRY_FLOOR`` (0.7x) of the fault-free wall-clock
  throughput — recovery is cheap, not just possible;
- **hedged**: retries + hedged reads additionally cut the *tail*: p95
  per-fetch wall time must come in under ``HEDGE_P95_FRACTION`` (0.9x) of
  retry-only's p95.  Hedges race a duplicate GET when a primary overruns
  ``hedge_factor`` x the wait EWMA; the duplicate draws a fresh tail
  ordinal, so it almost always beats a tail-struck primary.

Unlike the counter-modeled adaptive bench, this one REALLY sleeps (scaled
cross-region latency, ``LATENCY_SCALE``): hedging is a wall-clock race, so
its win only exists in wall-clock.  ``fetch_factor=1`` keeps one fetch ==
one sampled block == ~1 GET, making per-fetch timings attributable.

``run_resilience`` writes machine-readable ``BENCH_PR7.json``; the smoke
gate (``benchmarks/run.py --smoke``) fails CI unless all three claims hold.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_DATA_DIR, N_CELLS, N_GENES, emit

from repro.core import BlockShuffling, ScDataset
from repro.data import open_collection
from repro.data.synth import generate_tahoe_like

PR7_JSON = os.environ.get("BENCH_PR7_JSON", "BENCH_PR7.json")
RETRY_FLOOR = 0.7  # retry_only sps >= 0.7x fault-free sps
HEDGE_P95_FRACTION = 0.9  # hedged p95 fetch < 0.9x retry-only p95

M = 64  # minibatch size == one sampled block == ~1 GET per fetch
BLOCK = 64
FETCH_FACTOR = 1
PROFILE = "cross-region"
LATENCY_SCALE = 0.1  # 30ms first byte -> 3ms: real sleeps, CI-sized
ERROR_RATE = 0.05  # transient GET failure rate (per attempt)
TAIL_P = 0.10  # heavy-tail GET fraction
TAIL_MULT = 8.0  # tail GETs take 8x the modeled duration
RESILIENCE_BATCHES = int(os.environ.get("BENCH_RESILIENCE_BATCHES", "300"))

RETRY_KW = dict(retries=8, retry_backoff_s=0.002, retry_max_backoff_s=0.02)
HEDGE_KW = dict(hedge_factor=1.5, hedge_min_s=0.004)


def _uri(flaky: bool) -> str:
    # the heavy tail rides the CLOUD profile in every arm — it is a property
    # of the storage tier, not an injected fault, so the fault-free baseline
    # pays it too (the retry-throughput ratio isolates the cost of errors)
    cloud = (f"cloud://sharded-csr://{BENCH_DATA_DIR}?profile={PROFILE}"
             f"&latency_scale={LATENCY_SCALE}"
             f"&tail_p={TAIL_P}&tail_mult={TAIL_MULT}&tail_seed=1")
    if not flaky:
        return cloud
    return f"fault://{cloud}&seed=11&error_rate={ERROR_RATE}"


def _run_cell(name: str, *, flaky: bool, **resilience) -> dict:
    """Drain ``RESILIENCE_BATCHES`` fetches, timing each one; a fatal read
    error ends the cell (that is the no-retry control arm's job)."""
    col = open_collection(
        _uri(flaky), cache_bytes=8 << 20, block_rows=BLOCK, io_workers=4,
        **resilience,
    )
    ds = ScDataset(col, BlockShuffling(BLOCK), batch_size=M,
                   fetch_factor=FETCH_FACTOR, seed=0)
    times, samples, failed = [], 0, None
    t_all = time.perf_counter()
    try:
        it = ds.epochs(64)  # more epochs than the drain can consume
        for _ in range(RESILIENCE_BATCHES):
            t0 = time.perf_counter()
            b = next(it)
            times.append(time.perf_counter() - t0)
            samples += b.shape[0] if hasattr(b, "shape") else len(b)
    except (OSError, RuntimeError) as e:  # TransientStorageError / budget
        failed = f"{type(e).__name__}: {e}"
    total_s = time.perf_counter() - t_all
    snap = col.iostats.snapshot()
    out = {
        "failed": failed,
        "batches": len(times),
        "samples": samples,
        "total_seconds": total_s,
        "sps": samples / max(total_s, 1e-12),
        "p50_fetch_s": float(np.percentile(times, 50)) if times else None,
        "p95_fetch_s": float(np.percentile(times, 95)) if times else None,
        "retries": snap["retries"],
        "retry_wait_s": snap["retry_wait_s"],
        "hedges_issued": snap["hedges_issued"],
        "hedges_won": snap["hedges_won"],
        "requests": snap["requests"],
    }
    faults = col.stats().get("faults")
    if faults is not None:
        out["faults"] = faults
    col.release()
    emit(name, 1e6 / max(out["sps"], 1e-9),
         f"sps={out['sps']:.1f};p95_ms={(out['p95_fetch_s'] or 0)*1e3:.1f};"
         f"retries={out['retries']};hedges={out['hedges_issued']};"
         f"failed={failed is not None}")
    return out


def run_resilience(write_json: bool = True) -> dict:
    generate_tahoe_like(BENCH_DATA_DIR, n_cells=N_CELLS, n_genes=N_GENES,
                        seed=0)
    fault_free = _run_cell("resilience_fault_free", flaky=False)
    no_retry = _run_cell("resilience_no_retry", flaky=True)
    retry_only = _run_cell("resilience_retry_only", flaky=True, **RETRY_KW)
    hedged = _run_cell("resilience_hedged", flaky=True, **RETRY_KW,
                       **HEDGE_KW)

    control_ok = no_retry["failed"] is not None
    sps_ratio = retry_only["sps"] / max(fault_free["sps"], 1e-12)
    retry_ok = retry_only["failed"] is None and sps_ratio >= RETRY_FLOOR
    p95_ratio = (hedged["p95_fetch_s"] or 1e9) / max(
        retry_only["p95_fetch_s"] or 1e-12, 1e-12)
    hedge_ok = (hedged["failed"] is None
                and hedged["hedges_issued"] > 0
                and p95_ratio < HEDGE_P95_FRACTION)
    ok = control_ok and retry_ok and hedge_ok
    emit("resilience_gates", 0.0,
         f"no_retry_failed={control_ok};sps_ratio={sps_ratio:.2f}"
         f"(floor={RETRY_FLOOR});p95_ratio={p95_ratio:.2f}"
         f"(ceil={HEDGE_P95_FRACTION});pass={ok}")
    out = {
        "bench": "resilience",
        "fixture": {
            "profile": PROFILE,
            "latency_scale": LATENCY_SCALE,
            "error_rate": ERROR_RATE,
            "tail_p": TAIL_P,
            "tail_mult": TAIL_MULT,
            "batch_size": M,
            "fetch_factor": FETCH_FACTOR,
            "block_rows": BLOCK,
            "batches": RESILIENCE_BATCHES,
            "retry": RETRY_KW,
            "hedge": HEDGE_KW,
        },
        "fault_free": fault_free,
        "no_retry": no_retry,
        "retry_only": retry_only,
        "hedged": hedged,
        "gates": {
            "no_retry_failed": control_ok,
            "retry_sps_ratio": sps_ratio,
            "retry_floor": RETRY_FLOOR,
            "hedge_p95_ratio": p95_ratio,
            "hedge_p95_fraction": HEDGE_P95_FRACTION,
        },
        "pass": bool(ok),
    }
    if write_json:
        with open(PR7_JSON, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"# wrote {PR7_JSON}")
    return out


def run() -> dict:
    return run_resilience(write_json=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    raise SystemExit(0 if run()["pass"] else 1)
