"""Paper Fig. 3 — sequential streaming throughput vs fetch factor.

Claim under test: even with no shuffling at all, raising the fetch factor
amortizes per-call I/O overhead; the paper reports >15x over AnnLoader-style
iterative minibatch fetching at f=1024.

Runs through the unified backend layer (`open_collection`): sequential
fetches are planned as contiguous runs split only at the plate-shard
boundaries, and the planner-level IOStats (runs / bytes) are reported per
cell.  The block cache is DISABLED here on purpose: with it on, a small
fetch factor borrows the amortization from cached neighbor rows (a 256-row
block read serves four f=1 fetches) and the per-call-overhead claim this
figure tests would be confounded — the cache's run reduction is reported by
``bench_fig2_throughput``'s planner summary instead.
"""
from __future__ import annotations

from benchmarks.common import emit, planned_dataset, timed_samples_per_sec

from repro.core import ScDataset, Streaming

M = 64
GRID_F = (1, 4, 16, 64, 256, 1024)


def run() -> dict:
    col, stats = planned_dataset(cache_bytes=0, block_rows=M)
    results = {}
    base = None
    for f in GRID_F:
        if M * f > len(col):
            # drop_last would drain ZERO batches and report a nonsense 0.0
            # sps for this cell (possible when BENCH_N_CELLS is shrunk)
            emit(f"fig3_streaming_f{f}", 0.0,
                 f"skipped=fetch_size_{M * f}_exceeds_n_{len(col)}")
            continue
        ds = ScDataset(
            col, Streaming(), batch_size=M, fetch_factor=f, seed=0,
            batch_transform=lambda bb: bb.to_dense(),
        )
        r = timed_samples_per_sec(iter(ds), stats, batch_size=M)
        results[f] = r
        if f == 1:
            base = r
        emit(
            f"fig3_streaming_f{f}",
            1e6 / max(r["sps_modeled"], 1e-9),
            f"sps_modeled={r['sps_modeled']:.1f};sps_wall={r['sps_wall']:.0f};"
            f"calls={r['io_calls']};runs={r['io_runs']};bytes={r['bytes_read']}",
        )
    f_max = max(results)  # largest f actually run
    speedup = results[f_max]["sps_modeled"] / max(base["sps_modeled"], 1e-9)
    emit(f"fig3_speedup_f{f_max}_vs_f1", 0.0,
         f"speedup={speedup:.1f}x;paper_claim=15x")
    return {"results": results, "speedup": speedup}


if __name__ == "__main__":
    run()
