"""Paper Fig. 3 — sequential streaming throughput vs fetch factor.

Claim under test: even with no shuffling at all, raising the fetch factor
amortizes per-call I/O overhead; the paper reports >15x over AnnLoader-style
iterative minibatch fetching at f=1024.
"""
from __future__ import annotations

from benchmarks.common import dataset, emit, timed_samples_per_sec

from repro.core import ScDataset, Streaming

M = 64
GRID_F = (1, 4, 16, 64, 256, 1024)


def run() -> dict:
    store, stats = dataset()
    results = {}
    base = None
    for f in GRID_F:
        ds = ScDataset(
            store, Streaming(), batch_size=M, fetch_factor=f, seed=0,
            batch_transform=lambda bb: bb.to_dense(),
        )
        r = timed_samples_per_sec(iter(ds), stats, batch_size=M)
        results[f] = r
        if f == 1:
            base = r
        emit(
            f"fig3_streaming_f{f}",
            1e6 / max(r["sps_modeled"], 1e-9),
            f"sps_modeled={r['sps_modeled']:.1f};sps_wall={r['sps_wall']:.0f};"
            f"calls={r['io_calls']}",
        )
    speedup = results[GRID_F[-1]]["sps_modeled"] / max(base["sps_modeled"], 1e-9)
    emit("fig3_speedup_f1024_vs_f1", 0.0,
         f"speedup={speedup:.1f}x;paper_claim=15x")
    return {"results": results, "speedup": speedup}


if __name__ == "__main__":
    run()
