"""Shared benchmark fixtures and reporting helpers.

All paper benchmarks run against one synthetic Tahoe-like dataset (plate
structure per DESIGN.md §2) generated once under BENCH_DATA_DIR.  Two time
bases are reported everywhere:

- ``wall``    — measured wall-clock on this container's page-cached mmap
  (real, but the random-access penalty is mild here);
- ``modeled`` — wall + the SATA-SSD/HDF5 storage model from
  repro/data/iostats.py (calibrated so 1-random-row-per-sample reads give
  ~20 samples/s, the paper's AnnLoader baseline).  Speedup *ratios* in the
  modeled base are the paper-comparable numbers.
"""
from __future__ import annotations

import os
import sys
import time
from typing import Callable, Iterable, Optional

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import (  # noqa: E402
    SATA_SSD,
    IOStats,
    generate_tahoe_like,
    load_tahoe_like,
    open_collection,
)
from repro.pipeline import Pipeline  # noqa: E402

BENCH_DATA_DIR = os.environ.get("BENCH_DATA_DIR", "/tmp/repro_bench_data")
N_CELLS = int(os.environ.get("BENCH_N_CELLS", "150000"))
N_GENES = int(os.environ.get("BENCH_N_GENES", "2048"))
MEASURE_S = float(os.environ.get("BENCH_MEASURE_S", "1.5"))

_ROWS: list[dict] = []


def dataset(simulate_sata: bool = True):
    """(store, iostats) over the shared fixture; modeled time enabled, no sleeping."""
    paths = generate_tahoe_like(BENCH_DATA_DIR, n_cells=N_CELLS, n_genes=N_GENES, seed=0)
    stats = IOStats(simulate=SATA_SSD if simulate_sata else None, simulate_scale=0.0)
    store = load_tahoe_like(BENCH_DATA_DIR, iostats=stats)
    return store, stats


def planned_dataset(
    simulate_sata: bool = True,
    *,
    cache_bytes: int = 64 << 20,
    block_rows: int = 256,
    max_extent_rows: int = 32768,
    io_workers: int = 1,
    readahead: int = 0,
    admission: str = "always",
    simulate_scale: float = 0.0,
):
    """(collection, iostats) through the unified backend layer.

    Same on-disk fixture as :func:`dataset`, but fetches run through the
    cross-shard read planner + LRU block cache, and IOStats (runs / bytes /
    cache hits) is recorded once at the planner level.  ``io_workers`` /
    ``readahead`` / ``admission`` switch on the async planned-execution
    path; ``simulate_scale > 0`` makes each physical read SLEEP its modeled
    storage latency (scaled), so concurrency shows up in wall-clock.
    """
    generate_tahoe_like(BENCH_DATA_DIR, n_cells=N_CELLS, n_genes=N_GENES, seed=0)
    stats = IOStats(
        simulate=SATA_SSD if simulate_sata else None, simulate_scale=simulate_scale
    )
    col = open_collection(
        "sharded-csr://" + BENCH_DATA_DIR,
        iostats=stats,
        cache_bytes=cache_bytes,
        block_rows=block_rows,
        max_extent_rows=max_extent_rows,
        io_workers=io_workers,
        readahead=readahead,
        admission=admission,
    )
    return col, stats


# One shared comparison point for every async-vs-sync measurement (fig2,
# table2): scattered sampling (b=16) over fine cache blocks with the cache
# sized well below the drained working set (so the steady state stays
# miss-heavy and there is real I/O latency to overlap) but above ~2 fetches
# of blocks (so readahead staging is never evicted before consumption).
# The sim scale keeps slept I/O latency dominant over python/assembly CPU,
# as it is on the SATA/HDF5 hardware the paper measures.  Retune HERE.
ASYNC_CELL = {"b": 16, "f": 16, "cache_bytes": 16 << 20, "block_rows": 64}
ASYNC_SIM_SCALE = float(os.environ.get("BENCH_SIM_SCALE", "0.15"))


def async_cell_pipeline(
    *,
    io_workers: int,
    readahead: int,
    batch_size: int = 64,
    num_workers: int = 0,
    simulate_scale: float = None,
    iostats: Optional[IOStats] = None,
):
    """The shared comparison cell, declared through the Pipeline API.

    Returns ``(pipe, stats)`` over a COLD collection on the shared fixture
    with slept per-read latency — every sync-vs-async (and pipeline-parity)
    measurement is this one declaration with different concurrency knobs.
    """
    generate_tahoe_like(BENCH_DATA_DIR, n_cells=N_CELLS, n_genes=N_GENES, seed=0)
    scale = ASYNC_SIM_SCALE if simulate_scale is None else simulate_scale
    stats = iostats if iostats is not None else IOStats(
        simulate=SATA_SSD, simulate_scale=scale
    )
    pipe = (
        Pipeline.from_uri(
            "sharded-csr://" + BENCH_DATA_DIR,
            cache_bytes=ASYNC_CELL["cache_bytes"],
            block_rows=ASYNC_CELL["block_rows"],
            io_workers=io_workers,
            readahead=readahead,
            iostats=stats,
        )
        .strategy("block", block_size=ASYNC_CELL["b"])
        .batch(batch_size, fetch_factor=ASYNC_CELL["f"])
        .seed(0)
        .prefetch(workers=num_workers)
        .build(batch_transform=lambda bb: bb.to_dense())
    )
    return pipe, stats


def drain(it, stats: IOStats, *, n_batches: int, batch_size: int) -> dict:
    """Reset stats, drain ``n_batches``, report throughput + IOStats.

    ``sps_modeled`` uses the repo's standard time base (wall + un-slept
    modeled storage time, cf. :meth:`IOStats.total_seconds`) — the
    paper-comparable number, and far less exposed to host scheduler noise
    than raw wall-clock.
    """
    stats.reset()
    n = 0
    t0 = time.perf_counter()
    for _ in it:
        n += 1
        if n >= n_batches:
            break
    wall = time.perf_counter() - t0
    samples = n * batch_size
    modeled = wall + stats.modeled_s * max(
        0.0, 1.0 - (stats.simulate_scale if stats.simulate is not None else 1.0)
    )
    return {
        "samples": samples,
        "sps_wall": samples / max(wall, 1e-9),
        "sps_modeled": samples / max(modeled, 1e-9),
        "runs_per_sample": stats.runs / max(1, stats.rows),
        "cache_hit_rate": stats.cache_hit_rate,
        "prefetched_blocks": stats.prefetched,
        "bytes_read": stats.bytes_read,
    }


def async_equal_work(
    *,
    io_workers: int,
    readahead: int,
    n_batches: int,
    batch_size: int = 64,
    num_workers: int = 0,
) -> dict:
    """Drain ``n_batches`` from a COLD planned collection with slept per-read
    latency (``ASYNC_SIM_SCALE``); wall-clock is the only thing that may
    differ between sync and async — delivery is bit-identical."""
    pipe, stats = async_cell_pipeline(
        io_workers=io_workers, readahead=readahead, batch_size=batch_size,
        num_workers=num_workers,
    )
    out = drain(iter(pipe), stats, n_batches=n_batches, batch_size=batch_size)
    pipe.close()
    return {"io_workers": io_workers, "readahead": readahead, **out}


def cloud_collection(
    profile: str,
    *,
    latency_scale: float,
    iostats: Optional[IOStats] = None,
    cache_bytes: int = 0,
    io_workers: int = 1,
    readahead: int = 0,
):
    """(collection, iostats) over the shared fixture behind ``cloud://``
    request semantics: every planner extent is one simulated GET (first-byte
    latency + bandwidth + in-flight cap from the named
    :data:`repro.data.CLOUD_PROFILES` entry, sleeps scaled by
    ``latency_scale``).  ``IOStats.requests`` counts the GETs."""
    generate_tahoe_like(BENCH_DATA_DIR, n_cells=N_CELLS, n_genes=N_GENES, seed=0)
    stats = iostats if iostats is not None else IOStats()
    col = open_collection(
        f"cloud://sharded-csr://{BENCH_DATA_DIR}"
        f"?profile={profile}&latency_scale={latency_scale}",
        iostats=stats,
        cache_bytes=cache_bytes,
        io_workers=io_workers,
        readahead=readahead,
    )
    return col, stats


def timed_samples_per_sec(
    it: Iterable,
    stats: IOStats,
    *,
    batch_size: int,
    measure_s: Optional[float] = None,
    max_batches: int = 10_000,
) -> dict:
    """Drain ``it`` for ~measure_s; return wall + modeled throughput."""
    measure_s = MEASURE_S if measure_s is None else measure_s
    stats.reset()
    n = 0
    t0 = time.perf_counter()
    for batch in it:
        n += 1
        if time.perf_counter() - t0 > measure_s or n >= max_batches:
            break
    wall = time.perf_counter() - t0
    modeled = wall + stats.modeled_s
    samples = n * batch_size
    return {
        "samples": samples,
        "wall_s": wall,
        "modeled_s": modeled,
        "sps_wall": samples / max(wall, 1e-9),
        "sps_modeled": samples / max(modeled, 1e-9),
        "io_runs": stats.runs,
        "io_calls": stats.calls,
        "bytes_read": stats.bytes_read,
        "cache_hits": stats.cache_hits,
        "cache_misses": stats.cache_misses,
        "cache_hit_rate": stats.cache_hit_rate,
    }


def emit(name: str, us_per_call: float, derived: str) -> None:
    """One CSV row in the required ``name,us_per_call,derived`` format."""
    _ROWS.append({"name": name, "us": us_per_call, "derived": derived})
    print(f"{name},{us_per_call:.3f},{derived}")


def all_rows() -> list[dict]:
    return list(_ROWS)
