#!/usr/bin/env python
"""Docs-freshness gate (run in CI; see .github/workflows/ci.yml).

Two checks keep README.md honest against the code:

1. **Scheme table coverage** — import the live backend registry
   (``repro.data.registered_schemes``) and fail if any registered URI scheme
   is missing from the README (a new ``@register_backend`` without a row in
   the storage-backends table fails the build, not a reviewer's memory).
2. **Executable quickstart** — extract the FIRST fenced ``python`` block
   from the README and ``exec`` it.  The snippet is the repo's front door;
   if it drifts from the API it breaks here, loudly.

Exit code 0 = docs fresh; nonzero with a pointed message otherwise.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

README = os.path.join(REPO, "README.md")


def check_scheme_table(readme_text: str) -> list[str]:
    """Every registered scheme must appear as `scheme://` in the README."""
    from repro.data import registered_schemes

    missing = [
        s for s in registered_schemes() if f"`{s}://" not in readme_text
    ]
    return missing


def extract_quickstart(readme_text: str) -> str:
    m = re.search(r"```python\n(.*?)```", readme_text, flags=re.DOTALL)
    if m is None:
        raise SystemExit("FAIL: README.md has no ```python quickstart block")
    return m.group(1)


def run_quickstart(snippet: str) -> None:
    code = compile(snippet, "README.md:quickstart", "exec")
    exec(code, {"__name__": "__quickstart__"})


def main() -> int:
    with open(README) as f:
        text = f.read()

    missing = check_scheme_table(text)
    if missing:
        print(
            f"FAIL: registered scheme(s) missing from README.md's "
            f"storage-backends table: {missing}\n"
            "      add a row per scheme (format: | `scheme://` | ... |)"
        )
        return 1
    from repro.data import registered_schemes

    print(f"OK: all {len(registered_schemes())} registered schemes documented "
          f"({', '.join(registered_schemes())})")

    snippet = extract_quickstart(text)
    try:
        run_quickstart(snippet)
    except Exception as e:  # noqa: BLE001 - report, fail the gate
        print(f"FAIL: README quickstart snippet raised {type(e).__name__}: {e}")
        raise
    print("OK: README quickstart snippet executed end to end")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
