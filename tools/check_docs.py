#!/usr/bin/env python
"""Docs-freshness gate (run in CI; see .github/workflows/ci.yml).

Three checks keep the docs honest against the code:

1. **Scheme table coverage** — import the live backend registry
   (``repro.data.registered_schemes``) and fail if any registered URI scheme
   is missing from the README (a new ``@register_backend`` without a row in
   the storage-backends table fails the build, not a reviewer's memory).
2. **Executable quickstart** — extract the FIRST fenced ``python`` block
   from the README and ``exec`` it.  The snippet is the repo's front door;
   if it drifts from the API it breaks here, loudly.
3. **DataSpec field reference** — every field of
   ``repro.pipeline.DataSpec`` must appear as a ``| `field` |`` row in
   ``docs/pipeline.md`` (the spec-field reference is generated from the
   dataclass; adding a field without documenting it fails the build).
4. **IOStats counter table** — every counter in the analyzer's registry
   (``tools.analyze.contracts.iostats_counter_names``, i.e. the
   ``PendingIO`` dataclass fields — the same list the iostats-pairing
   contract check enforces) must appear as a ``| `counter` |`` row in
   ``docs/architecture.md``.
5. **Serving knob table** — every field of
   ``repro.serve.data.ServeConfig`` must appear as a ``| `knob` |`` row in
   ``docs/serving.md`` (a new server knob without a documented row fails
   the build).

Exit code 0 = docs fresh; nonzero with a pointed message otherwise.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)  # for tools.analyze (the counter registry)

README = os.path.join(REPO, "README.md")
PIPELINE_DOC = os.path.join(REPO, "docs", "pipeline.md")
ARCH_DOC = os.path.join(REPO, "docs", "architecture.md")
SERVING_DOC = os.path.join(REPO, "docs", "serving.md")
IOSTATS_SRC = os.path.join(REPO, "src", "repro", "data", "iostats.py")


def check_scheme_table(readme_text: str) -> list[str]:
    """Every registered scheme must appear as `scheme://` in the README."""
    from repro.data import registered_schemes

    missing = [
        s for s in registered_schemes() if f"`{s}://" not in readme_text
    ]
    return missing


def check_spec_fields(pipeline_doc_text: str) -> list[str]:
    """Every DataSpec field needs a ``| `field` |`` row in docs/pipeline.md."""
    import dataclasses

    from repro.pipeline import DataSpec

    return [
        f.name
        for f in dataclasses.fields(DataSpec)
        if f"| `{f.name}`" not in pipeline_doc_text
    ]


def spec_field_table() -> str:
    """The reference table skeleton, straight from the dataclass — paste
    into docs/pipeline.md when fields change (``python tools/check_docs.py
    --spec-table``)."""
    import dataclasses

    from repro.pipeline import DataSpec

    rows = ["| Field | Default | Meaning |", "|---|---|---|"]
    for f in dataclasses.fields(DataSpec):
        if f.default is not dataclasses.MISSING:
            default = repr(f.default)
        elif f.default_factory is not dataclasses.MISSING:  # type: ignore[misc]
            default = repr(f.default_factory())  # type: ignore[misc]
        else:
            default = ""
        rows.append(f"| `{f.name}` | `{default}` | TODO |")
    return "\n".join(rows)


def check_iostats_counters(arch_doc_text: str) -> list[str]:
    """Every IOStats counter needs a ``| `counter` |`` row in
    docs/architecture.md.  The counter list comes from the static
    analyzer's registry (PendingIO's fields, read via AST — no import of
    the analyzed module), so the docs table, the runtime counters and the
    iostats-pairing contract check all share one source of truth."""
    from tools.analyze.contracts import iostats_counter_names

    counters = iostats_counter_names(IOSTATS_SRC)
    if not counters:
        return ["<no PendingIO counters found in src/repro/data/iostats.py>"]
    return [c for c in counters if f"| `{c}`" not in arch_doc_text]


def check_serve_knobs(serving_doc_text: str) -> list[str]:
    """Every ServeConfig field needs a ``| `knob` |`` row in
    docs/serving.md — the server's whole surface is declarative, so its
    documentation is checkable the same way DataSpec's is."""
    import dataclasses

    from repro.serve.data import ServeConfig

    return [
        f.name
        for f in dataclasses.fields(ServeConfig)
        if f"| `{f.name}`" not in serving_doc_text
    ]


def extract_quickstart(readme_text: str) -> str:
    m = re.search(r"```python\n(.*?)```", readme_text, flags=re.DOTALL)
    if m is None:
        raise SystemExit("FAIL: README.md has no ```python quickstart block")
    return m.group(1)


def run_quickstart(snippet: str) -> None:
    code = compile(snippet, "README.md:quickstart", "exec")
    exec(code, {"__name__": "__quickstart__"})


def main() -> int:
    if "--spec-table" in sys.argv[1:]:
        print(spec_field_table())
        return 0
    with open(README) as f:
        text = f.read()

    missing = check_scheme_table(text)
    if missing:
        print(
            f"FAIL: registered scheme(s) missing from README.md's "
            f"storage-backends table: {missing}\n"
            "      add a row per scheme (format: | `scheme://` | ... |)"
        )
        return 1
    from repro.data import registered_schemes

    print(f"OK: all {len(registered_schemes())} registered schemes documented "
          f"({', '.join(registered_schemes())})")

    snippet = extract_quickstart(text)
    try:
        run_quickstart(snippet)
    except Exception as e:  # noqa: BLE001 - report, fail the gate
        print(f"FAIL: README quickstart snippet raised {type(e).__name__}: {e}")
        raise
    print("OK: README quickstart snippet executed end to end")

    if not os.path.exists(PIPELINE_DOC):
        print("FAIL: docs/pipeline.md (DataSpec field reference) is missing")
        return 1
    with open(PIPELINE_DOC) as f:
        undocumented = check_spec_fields(f.read())
    if undocumented:
        print(
            f"FAIL: DataSpec field(s) missing from docs/pipeline.md: "
            f"{undocumented}\n"
            "      regenerate the table skeleton with "
            "`python tools/check_docs.py --spec-table`"
        )
        return 1
    print("OK: every DataSpec field documented in docs/pipeline.md")

    if not os.path.exists(ARCH_DOC):
        print("FAIL: docs/architecture.md (IOStats counter table) is missing")
        return 1
    with open(ARCH_DOC) as f:
        missing_counters = check_iostats_counters(f.read())
    if missing_counters:
        print(
            f"FAIL: IOStats counter(s) missing from docs/architecture.md's "
            f"counter table: {missing_counters}\n"
            "      add a | `counter` | row per PendingIO field"
        )
        return 1
    print("OK: every IOStats counter documented in docs/architecture.md")

    if not os.path.exists(SERVING_DOC):
        print("FAIL: docs/serving.md (ServeConfig knob table) is missing")
        return 1
    with open(SERVING_DOC) as f:
        missing_knobs = check_serve_knobs(f.read())
    if missing_knobs:
        print(
            f"FAIL: ServeConfig knob(s) missing from docs/serving.md: "
            f"{missing_knobs}\n"
            "      add a | `knob` | row per ServeConfig field"
        )
        return 1
    print("OK: every ServeConfig knob documented in docs/serving.md")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
