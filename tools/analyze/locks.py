"""Check family 1 — lock discipline.

Two checks over every class in the model:

- ``unlocked-access``: a read/write of a ``# guarded-by: <lock>`` attribute
  outside a ``with self.<lock>:`` block (suppress a deliberate racy read
  with ``# unlocked-ok: <reason>``).  Constructors (``__init__`` /
  ``__post_init__``) are exempt — the object is not shared yet.
- ``blocking-under-lock``: a blocking call made while a ``threading.Lock``
  / ``RLock`` is held — ``Future.result``, ``sleep``, ``os.pread``,
  ``.acquire()``, executor ``shutdown``/``wait``/``join``, adapter
  ``read_range`` (physical I/O), or acquiring a semaphore slot.  Holding a
  hot-path mutex across any of those serializes every concurrent fetch on
  one straggler.  Suppress with ``# blocking-ok: <reason>``.

Plus ``bad-annotation`` for guard names that are not a lock attribute of
the class (and not the reserved ``external``).

Scope (by design): access checking is per owning class — cross-object
reads of another instance's fields (e.g. a controller reading monotonic
cache counters) are the owning class's documented contract, not lint.
"""
from __future__ import annotations

import ast
from typing import Optional

from .model import EXTERNAL, ClassInfo, ModuleInfo, SourceModel
from .report import Finding

CONSTRUCTORS = ("__init__", "__post_init__", "__del__")

#: method names whose call is assumed to block (on any receiver)
BLOCKING_ATTR_CALLS = {
    "result", "acquire", "wait", "shutdown", "join", "pread", "sleep",
    "read_range",
}
BLOCKING_NAME_CALLS = {"sleep", "pread"}


def _dotted(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _suppressed(line: int, lines: set[int]) -> bool:
    return line in lines or (line - 1) in lines


def _blocking_call(call: ast.Call) -> Optional[str]:
    """Human name of the blocking operation, or None."""
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id if fn.id in BLOCKING_NAME_CALLS else None
    if not isinstance(fn, ast.Attribute):
        return None
    if fn.attr not in BLOCKING_ATTR_CALLS:
        return None
    recv = _dotted(fn.value)
    if fn.attr == "join" and (
        isinstance(fn.value, ast.Constant) or recv in ("os.path", "posixpath")
    ):
        return None  # str.join / path join — not a blocking primitive
    return f"{recv}.{fn.attr}" if recv else fn.attr


def _with_lock_attrs(node: ast.With, cls: ClassInfo) -> list[str]:
    """Lock attributes of ``cls`` acquired by this with-statement."""
    out = []
    for item in node.items:
        e = item.context_expr
        if (
            isinstance(e, ast.Attribute)
            and isinstance(e.value, ast.Name)
            and e.value.id == "self"
            and e.attr in cls.locks
        ):
            out.append(e.attr)
    return out


class _MethodWalker:
    def __init__(self, cls: ClassInfo, mod: ModuleInfo, findings: list[Finding]):
        self.cls = cls
        self.mod = mod
        self.findings = findings

    def walk(self, node: ast.AST, held: frozenset) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            # a nested def/lambda runs later, on an unknown thread with no
            # locks inherited — analyze its body with an empty held set
            body = node.body if not isinstance(node, ast.Lambda) else [node.body]
            for child in body:
                self.walk(child, frozenset())
            return
        if isinstance(node, ast.With):
            acquired = _with_lock_attrs(node, self.cls)
            for item in node.items:
                self.walk(item.context_expr, held)
            for attr in acquired:
                site = self.cls.locks[attr]
                if site.kind == "semaphore" and self._exclusive_held(held):
                    self._blocking(node.lineno, f"semaphore self.{attr} acquire",
                                   held)
            inner = held.union(acquired)
            for child in node.body:
                self.walk(child, inner)
            return
        if isinstance(node, ast.Attribute):
            self._check_access(node, held)
        elif isinstance(node, ast.Call):
            op = _blocking_call(node)
            if op is not None and self._exclusive_held(held):
                self._blocking(node.lineno, f"{op}()", held)
        for child in ast.iter_child_nodes(node):
            self.walk(child, held)

    # ---------------------------------------------------------------- bits
    def _exclusive_held(self, held: frozenset) -> Optional[str]:
        for attr in held:
            if self.cls.locks[attr].is_exclusive:
                return attr
        return None

    def _blocking(self, line: int, op: str, held: frozenset) -> None:
        if _suppressed(line, self.mod.blocking_ok):
            return
        lock = self._exclusive_held(held)
        self.findings.append(Finding(
            check="blocking-under-lock",
            file=self.mod.file,
            line=line,
            symbol=f"{self.cls.name}.{self._method}",
            message=(
                f"blocking call {op} while holding self.{lock} in "
                f"{self.cls.name}.{self._method}"
            ),
        ))

    def _check_access(self, node: ast.Attribute, held: frozenset) -> None:
        if not (isinstance(node.value, ast.Name) and node.value.id == "self"):
            return
        entry = self.cls.guarded.get(node.attr)
        if entry is None:
            return
        guard, _ = entry
        if guard == EXTERNAL or guard in held:
            return
        if _suppressed(node.lineno, self.mod.unlocked_ok):
            return
        mode = "write" if isinstance(node.ctx, (ast.Store, ast.Del)) else "read"
        self.findings.append(Finding(
            check="unlocked-access",
            file=self.mod.file,
            line=node.lineno,
            symbol=f"{self.cls.name}.{node.attr}",
            message=(
                f"{mode} of self.{node.attr} (guarded-by: {guard}) outside "
                f"`with self.{guard}:` in {self.cls.name}.{self._method}"
            ),
        ))

    def run(self, method: str, fn: ast.FunctionDef) -> None:
        self._method = method
        for child in fn.body:
            self.walk(child, frozenset())


def check_locks(model: SourceModel) -> list[Finding]:
    findings: list[Finding] = []
    for mod in model.modules.values():
        for cls in mod.classes:
            for attr, (guard, line) in sorted(cls.guarded.items()):
                if guard != EXTERNAL and guard not in cls.locks:
                    findings.append(Finding(
                        check="bad-annotation",
                        file=mod.file,
                        line=line,
                        symbol=f"{cls.name}.{attr}",
                        message=(
                            f"guarded-by: {guard} on {cls.name}.{attr} names "
                            f"no Lock/RLock/Semaphore attribute of {cls.name} "
                            f"(known: {sorted(cls.locks) or 'none'}; use "
                            f"'external' for externally-serialized fields)"
                        ),
                    ))
            if not cls.guarded and not cls.locks:
                continue
            walker = _MethodWalker(cls, mod, findings)
            for mname, fn in cls.methods.items():
                if mname in CONSTRUCTORS:
                    continue
                walker.run(mname, fn)
    return findings
