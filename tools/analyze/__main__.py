"""CLI for the analyzer — ``python tools/analyze --src src``."""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):  # `python tools/analyze` (no parent package)
    _REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    sys.path.insert(0, _REPO)

from tools.analyze import build_model, run_all
from tools.analyze.lockorder import build_graph
from tools.analyze.report import apply_baseline, baseline_entry, load_baseline


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools/analyze",
        description="static concurrency & contract analyzer",
    )
    ap.add_argument("--src", default="src", help="source root to analyze")
    ap.add_argument("--baseline", default=None,
                    help="JSON baseline of accepted findings")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON (baseline-entry shaped)")
    ap.add_argument("--graph", action="store_true",
                    help="print the static lock graph and exit")
    args = ap.parse_args(argv)

    model = build_model(args.src)
    if args.graph:
        graph = build_graph(model)
        print(f"{len(graph.sites)} lock site(s):")
        for (file, line), lid in sorted(graph.sites.items()):
            print(f"  {lid} [{graph.kinds[lid]}] @ {file}:{line}")
        print(f"{len(graph.edges)} edge(s):")
        for a, b in sorted(graph.edges):
            file, line = graph.provenance[(a, b)]
            print(f"  {a} -> {b} @ {file}:{line}")
        return 0

    findings = run_all(args.src, model=model)
    fresh, stale = apply_baseline(findings, load_baseline(args.baseline))

    if args.json:
        print(json.dumps([baseline_entry(f) | {"line": f.line} for f in fresh],
                         indent=2))
    else:
        for f in fresh:
            print(f.render())
    for e in stale:
        print(
            f"warning: stale baseline entry (no longer found): "
            f"{e.get('check')} {e.get('file')} {e.get('symbol')}",
            file=sys.stderr,
        )
    n_base = len(findings) - len(fresh)
    print(
        f"analyze: {len(findings)} finding(s), {n_base} baselined, "
        f"{len(fresh)} blocking, {len(stale)} stale baseline entr(ies)",
        file=sys.stderr,
    )
    return 1 if fresh else 0


if __name__ == "__main__":
    raise SystemExit(main())
