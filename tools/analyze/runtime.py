"""Runtime lock-order witness — the dynamic cross-check of the static graph.

:class:`LockOrderWitness` monkeypatches the ``threading`` lock factories so
that locks created at the *exact source sites* the static analysis found
(``StaticLockGraph.sites``: ``(realpath, lineno)`` of the
``threading.Lock()`` call) come back wrapped in :class:`_WitnessLock`.
Wrapped locks keep a thread-local held stack and record an edge
``(held, acquired)`` on every successful acquisition.  Locks created
anywhere else — stdlib internals, queue mutexes, locals the analyzer does
not model — get the real factory object and are invisible.

After a concurrency test runs under the witness, every observed edge must
be a subset of the static graph's edges: an unpredicted edge means the
static analysis failed to see an acquisition path (a resolution gap to fix
or a genuinely dynamic order to document), which is precisely the blind
spot a purely static deadlock check cannot self-diagnose.

Usage (see ``tests/conftest.py``)::

    graph = static_lock_graph("src")
    witness = LockOrderWitness(graph)
    with witness.installed():
        ...  # run the concurrent workload
    assert not witness.unpredicted()
"""
from __future__ import annotations

import contextlib
import os
import sys
import threading
from typing import Iterator, Optional

from .lockorder import StaticLockGraph, static_lock_graph  # noqa: F401

_FACTORIES = ("Lock", "RLock", "Semaphore", "BoundedSemaphore")


class _WitnessLock:
    """A lock wrapper that reports acquisition order to its witness."""

    __slots__ = ("_real", "_id", "_witness")

    def __init__(self, real, lock_id: str, witness: "LockOrderWitness"):
        self._real = real
        self._id = lock_id
        self._witness = witness

    def acquire(self, blocking: bool = True, timeout: Optional[float] = None):
        # Lock wants timeout=-1 for "forever", Semaphore wants None — pass
        # the timeout through only when the caller gave one.
        if timeout is None:
            ok = self._real.acquire(blocking)
        else:
            ok = self._real.acquire(blocking, timeout)
        if ok:
            self._witness._on_acquire(self._id)
        return ok

    def release(self):
        self._witness._on_release(self._id)
        self._real.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._real.locked()

    def __getattr__(self, name):
        return getattr(self._real, name)


class LockOrderWitness:
    """Records (held -> acquired) edges for statically-known lock sites."""

    def __init__(self, graph: StaticLockGraph):
        self.graph = graph
        #: observed (holder id, acquired id) pairs
        self.edges: set[tuple[str, str]] = set()
        #: lock id -> times acquired (sanity: did the workload exercise it?)
        self.acquires: dict[str, int] = {}
        self._tl = threading.local()
        self._elock = threading.Lock()  # guards edges/acquires dicts
        self._saved: dict[str, object] = {}
        self._real_cache: dict[str, str] = {}
        self._installed = False

    # ------------------------------------------------------------ recording
    def _stack(self) -> list:
        st = getattr(self._tl, "stack", None)
        if st is None:
            st = self._tl.stack = []
        return st

    def _on_acquire(self, lock_id: str) -> None:
        st = self._stack()
        with self._elock:
            self.acquires[lock_id] = self.acquires.get(lock_id, 0) + 1
            for held in st:
                self.edges.add((held, lock_id))
        st.append(lock_id)

    def _on_release(self, lock_id: str) -> None:
        st = self._stack()
        # release order need not be LIFO; drop the most recent matching hold
        for i in range(len(st) - 1, -1, -1):
            if st[i] == lock_id:
                del st[i]
                break

    # ---------------------------------------------------------- patching
    def _site_of_caller(self) -> Optional[str]:
        frame = sys._getframe(2)  # factory wrapper -> creating code
        fname = frame.f_code.co_filename
        real = self._real_cache.get(fname)
        if real is None:
            real = self._real_cache[fname] = os.path.realpath(fname)
        return self.graph.sites.get((real, frame.f_lineno))

    def _wrap_factory(self, real_factory):
        witness = self

        def factory(*args, **kwargs):
            obj = real_factory(*args, **kwargs)
            lock_id = witness._site_of_caller()
            if lock_id is None:
                return obj
            return _WitnessLock(obj, lock_id, witness)

        return factory

    def install(self) -> None:
        if self._installed:
            return
        for name in _FACTORIES:
            self._saved[name] = getattr(threading, name)
            setattr(threading, name, self._wrap_factory(self._saved[name]))
        self._installed = True

    def uninstall(self) -> None:
        if not self._installed:
            return
        for name, real in self._saved.items():
            setattr(threading, name, real)
        self._saved.clear()
        self._installed = False

    @contextlib.contextmanager
    def installed(self) -> Iterator["LockOrderWitness"]:
        self.install()
        try:
            yield self
        finally:
            self.uninstall()

    # ----------------------------------------------------------- verdict
    def unpredicted(self) -> set[tuple[str, str]]:
        """Observed acquisition orders the static graph did not predict."""
        return self.edges - self.graph.edges

    def report(self) -> str:
        lines = [f"witness: {len(self.edges)} observed edge(s), "
                 f"{sum(self.acquires.values())} acquisition(s)"]
        for a, b in sorted(self.edges):
            tag = "ok" if (a, b) in self.graph.edges else "UNPREDICTED"
            lines.append(f"  {a} -> {b} [{tag}]")
        return "\n".join(lines)
