"""Check family 2 — the static lock-acquisition graph and its cycles.

Every lock is a creation site ``self.X = threading.Lock()`` identified as
``module.Class.attr``.  The graph has an edge ``A -> B`` when some thread
may acquire B while holding A:

- directly — a ``with self.B:`` nested inside ``with self.A:``;
- transitively — a call made under A to a method whose *may-acquire* set
  (fixed point over the call graph, with best-effort receiver typing from
  the source model and virtual dispatch through in-model subclasses)
  contains B.

A cycle in this graph is a potential deadlock (``lock-order-cycle``); a
self-edge on a non-reentrant ``threading.Lock`` is certain self-deadlock.
The analysis is deliberately conservative: unresolvable receivers
contribute nothing, so the graph can miss edges through dynamic dispatch —
which is exactly what the runtime witness (:mod:`tools.analyze.runtime`)
cross-checks: acquisition orders observed under the concurrency test
suites must be a subset of this graph.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Optional

from .model import ClassInfo, SourceModel, build_model
from .report import Finding


@dataclasses.dataclass
class StaticLockGraph:
    #: (holder id, acquired id) — ids are "module.Class.attr"
    edges: set[tuple[str, str]]
    #: (realpath of file, line of the threading.<Factory>() call) -> id
    sites: dict[tuple[str, int], str]
    #: id -> lock kind ("lock" | "rlock" | "semaphore")
    kinds: dict[str, str]
    #: (a, b) -> (file, line) of one statement inducing the edge
    provenance: dict[tuple[str, str], tuple[str, int]]


def _call_targets(
    model: SourceModel, cls: ClassInfo, call: ast.Call
) -> list[tuple[ClassInfo, str]]:
    """Possible (class, method) targets of a call made inside ``cls``."""
    fn = call.func
    if not isinstance(fn, ast.Attribute):
        return []
    mname = fn.attr
    recv = fn.value
    owner: Optional[ClassInfo] = None
    if isinstance(recv, ast.Name) and recv.id == "self":
        owner = cls
    elif (
        isinstance(recv, ast.Attribute)
        and isinstance(recv.value, ast.Name)
        and recv.value.id == "self"
    ):
        tname = cls.attr_types.get(recv.attr)
        owner = model.resolve_class(tname) if tname else None
    if owner is None:
        return []
    targets: list[tuple[ClassInfo, str]] = []
    found = model.find_method(owner, mname)
    if found is not None:
        targets.append((found[0], mname))
    for sub in model.subclasses(owner):  # virtual dispatch
        if mname in sub.methods:
            targets.append((sub, mname))
    return targets


def _locks_of_with(node: ast.With, cls: ClassInfo) -> list[str]:
    out = []
    for item in node.items:
        e = item.context_expr
        if (
            isinstance(e, ast.Attribute)
            and isinstance(e.value, ast.Name)
            and e.value.id == "self"
            and e.attr in cls.locks
        ):
            out.append(cls.lock_id(e.attr))
    return out


def _walk_method(
    model: SourceModel,
    cls: ClassInfo,
    fn: ast.FunctionDef,
    may_acquire: dict[tuple[str, str], set[str]],
    edges: dict[tuple[str, str], tuple[str, int]],
) -> set[str]:
    """Collect edges for one method; returns its DIRECT acquire set."""
    direct: set[str] = set()

    def visit(node: ast.AST, held: tuple[str, ...]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            body = node.body if not isinstance(node, ast.Lambda) else [node.body]
            for child in body:
                visit(child, ())  # runs later, no locks inherited
            return
        if isinstance(node, ast.With):
            acquired = _locks_of_with(node, cls)
            for item in node.items:
                visit(item.context_expr, held)
            inner = held
            for lid in acquired:
                direct.add(lid)
                for h in inner:
                    edges.setdefault((h, lid), (cls.file, node.lineno))
                inner = inner + (lid,)
            for child in node.body:
                visit(child, inner)
            return
        if isinstance(node, ast.Call) and held:
            for tcls, tm in _call_targets(model, cls, node):
                for lid in may_acquire.get((tcls.name, tm), set()):
                    for h in held:
                        edges.setdefault((h, lid), (cls.file, node.lineno))
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    for child in fn.body:
        visit(child, ())
    return direct


def _fixed_point(model: SourceModel) -> dict[tuple[str, str], set[str]]:
    """(class name, method) -> every lock id the call MAY acquire."""
    may: dict[tuple[str, str], set[str]] = {}
    calls: dict[tuple[str, str], list[tuple[str, str]]] = {}
    for cls in model.classes():
        for mname, fn in cls.methods.items():
            key = (cls.name, mname)
            direct: set[str] = set()
            callees: list[tuple[str, str]] = []
            for node in ast.walk(fn):
                if isinstance(node, ast.With):
                    direct.update(_locks_of_with(node, cls))
                elif isinstance(node, ast.Call):
                    callees.extend(
                        (t.name, m) for t, m in _call_targets(model, cls, node)
                    )
            may[key] = direct
            calls[key] = callees
    changed = True
    while changed:
        changed = False
        for key, callees in calls.items():
            cur = may[key]
            before = len(cur)
            for ck in callees:
                cur |= may.get(ck, set())
            if len(cur) != before:
                changed = True
    return may


def build_graph(model: SourceModel) -> StaticLockGraph:
    may = _fixed_point(model)
    edges: dict[tuple[str, str], tuple[str, int]] = {}
    sites: dict[tuple[str, int], str] = {}
    kinds: dict[str, str] = {}
    for cls in model.classes():
        for attr, site in cls.locks.items():
            lid = cls.lock_id(attr)
            sites[(os.path.realpath(site.file), site.line)] = lid
            kinds[lid] = site.kind
        for _, fn in cls.methods.items():
            _walk_method(model, cls, fn, may, edges)
    return StaticLockGraph(
        edges=set(edges), sites=sites, kinds=kinds, provenance=edges
    )


def static_lock_graph(src_root: str) -> StaticLockGraph:
    """Build the graph straight from a source tree (the witness entry)."""
    return build_graph(build_model(src_root))


def _cycles(edges: set[tuple[str, str]]) -> list[list[str]]:
    """Elementary cycles, via DFS over each node (graphs here are tiny)."""
    adj: dict[str, list[str]] = {}
    for a, b in sorted(edges):
        adj.setdefault(a, []).append(b)
    seen_cycles: set[tuple[str, ...]] = set()
    out: list[list[str]] = []

    def dfs(start: str, node: str, path: list[str]) -> None:
        for nxt in adj.get(node, []):
            if nxt == start:
                cyc = path[:]
                # canonicalize rotation so each cycle reports once
                i = cyc.index(min(cyc))
                canon = tuple(cyc[i:] + cyc[:i])
                if canon not in seen_cycles:
                    seen_cycles.add(canon)
                    out.append(list(canon))
            elif nxt not in path and nxt > start:
                # only explore nodes > start: every cycle is found from its
                # smallest node exactly once
                dfs(start, nxt, path + [nxt])

    for node in sorted(adj):
        dfs(node, node, [node])
    return out


def check_lock_order(model: SourceModel) -> list[Finding]:
    graph = build_graph(model)
    findings: list[Finding] = []
    for a, b in sorted(graph.edges):
        if a == b and graph.kinds.get(a) == "lock":
            file, line = graph.provenance[(a, b)]
            findings.append(Finding(
                check="lock-order-cycle",
                file=file,
                line=line,
                symbol=a,
                message=(
                    f"re-acquisition of non-reentrant lock {a} while already "
                    "held (certain self-deadlock)"
                ),
            ))
    for cyc in _cycles({(a, b) for a, b in graph.edges if a != b}):
        closing = (cyc[-1], cyc[0]) if len(cyc) > 1 else (cyc[0], cyc[0])
        file, line = graph.provenance.get(
            closing, graph.provenance.get((cyc[0], cyc[1] if len(cyc) > 1 else cyc[0]), ("?", 0))
        )
        findings.append(Finding(
            check="lock-order-cycle",
            file=file,
            line=line,
            symbol=" -> ".join(cyc),
            message=(
                "lock-order cycle (potential deadlock): "
                + " -> ".join(cyc + [cyc[0]])
            ),
        ))
    return findings
