"""Check family 3 — cross-cutting API contracts.

Three contracts that hold the repo's counters, caching semantics and
adapter registry together, each cheap to break silently in a refactor:

- ``iostats-pairing``: every counter in :class:`PendingIO` must have a
  matched pair of ``IOStats`` fields (main + ``spec_*``), be written by a
  recording method, appear in ``snapshot()``, be zeroed by ``reset()``,
  and be merged by ``commit()`` — so a new counter added in one place
  cannot silently vanish from the others.
- ``dataspec-classification``: every ``DataSpec`` field must be listed in
  exactly one of the module-level ``FINGERPRINT_FIELDS`` /
  ``CONTENT_FREE_FIELDS`` frozensets, with no stale names, and
  ``fingerprint()`` must consume ``CONTENT_FREE_FIELDS`` — machine-checking
  the refusal semantics: a spec field either changes delivered bytes (and
  the fingerprint) or is *explicitly* declared content-free.
- ``adapter-protocol``: every class reachable from a
  ``@register_backend(...)`` opener's return annotation must concretely
  implement the full storage contract (a body that is just ``raise
  NotImplementedError`` does not count), and wrapper adapters (those
  holding ``self.inner``) must forward ``bind_iostats`` / ``close``.
"""
from __future__ import annotations

import ast
from typing import Optional

from .model import ClassInfo, SourceModel, parse_file
from .report import Finding

#: methods every registered adapter must implement with a real body.
#: (boundaries / obs_keys / obs_column / bind_iostats / close have usable
#: StorageAdapter defaults and are only required on wrappers, below.)
ADAPTER_REQUIRED = (
    "__len__", "read_range", "take", "concat", "nbytes_of",
    "avg_row_bytes", "schema",
)
#: wrappers that hold an inner adapter must forward lifecycle calls too —
#: the default no-ops would silently drop iostats binding and leak handles.
WRAPPER_REQUIRED = ("bind_iostats", "close")


# --------------------------------------------------------------------------
# small AST helpers
# --------------------------------------------------------------------------

def _class_fields(cls: ClassInfo) -> list[tuple[str, int]]:
    """Class-level AnnAssign fields (dataclass counters), with lines."""
    out = []
    for stmt in cls.node.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            out.append((stmt.target.id, stmt.lineno))
    return out


def _self_write_targets(fn: ast.FunctionDef) -> set[str]:
    """Attributes of ``self`` written (Assign/AugAssign, incl. chained)."""
    out: set[str] = set()
    for node in ast.walk(fn):
        targets: list[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            if (
                isinstance(t, ast.Attribute)
                and isinstance(t.value, ast.Name)
                and t.value.id in ("self", "pend")
            ):
                out.add(t.attr)
    return out


def _dict_string_keys(fn: ast.FunctionDef) -> set[str]:
    out: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    out.add(k.value)
    return out


def _is_abstract_body(fn: ast.FunctionDef) -> bool:
    """True when the body is only doc/ellipsis/``raise NotImplementedError``."""
    real = [
        s for s in fn.body
        if not (isinstance(s, ast.Expr) and isinstance(s.value, ast.Constant))
    ]
    if not real:
        return True  # docstring/ellipsis only — a Protocol stub
    if len(real) == 1 and isinstance(real[0], ast.Raise):
        exc = real[0].exc
        name = ""
        if isinstance(exc, ast.Call):
            exc = exc.func
        if isinstance(exc, ast.Name):
            name = exc.id
        elif isinstance(exc, ast.Attribute):
            name = exc.attr
        return name == "NotImplementedError"
    return False


def _find_class(model: SourceModel, name: str) -> Optional[ClassInfo]:
    return model.resolve_class(name)


# --------------------------------------------------------------------------
# iostats pairing
# --------------------------------------------------------------------------

def iostats_counter_names(model_or_path) -> list[str]:
    """The canonical counter list: PendingIO's dataclass fields.

    Accepts a built :class:`SourceModel` or a path to ``iostats.py`` (the
    docs gate calls it with the file path to stay import-free).
    """
    if isinstance(model_or_path, SourceModel):
        cls = _find_class(model_or_path, "PendingIO")
        return [n for n, _ in _class_fields(cls)] if cls else []
    info = parse_file(model_or_path, src_root="/")
    for cls in info.classes:
        if cls.name == "PendingIO":
            return [n for n, _ in _class_fields(cls)]
    return []


def _commit_is_generic(fn: ast.FunctionDef) -> bool:
    """commit() iterating ``dataclasses.fields(PendingIO)`` merges every
    counter pair by construction."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            fname = f.attr if isinstance(f, ast.Attribute) else getattr(f, "id", "")
            if fname == "fields" and any(
                isinstance(a, ast.Name) and a.id == "PendingIO" for a in node.args
            ):
                return True
    return False


def check_iostats(model: SourceModel) -> list[Finding]:
    pend = _find_class(model, "PendingIO")
    stats = _find_class(model, "IOStats")
    if pend is None or stats is None:
        return []  # not this repo's layout — nothing to check
    findings: list[Finding] = []
    counters = _class_fields(pend)
    stat_fields = {n for n, _ in _class_fields(stats)}
    writes: set[str] = set()
    for mname, fn in stats.methods.items():
        if mname not in ("reset", "snapshot", "commit", "__post_init__"):
            writes |= _self_write_targets(fn)
    snap = stats.methods.get("snapshot")
    snap_keys = _dict_string_keys(snap) if snap else set()
    reset = stats.methods.get("reset")
    reset_targets = _self_write_targets(reset) if reset else set()
    commit = stats.methods.get("commit")
    commit_generic = commit is not None and _commit_is_generic(commit)

    def miss(counter: str, line: int, what: str) -> None:
        findings.append(Finding(
            check="iostats-pairing",
            file=stats.file,
            line=line,
            symbol=f"IOStats.{counter}",
            message=f"counter {counter!r} (PendingIO) {what}",
        ))

    for name, line in counters:
        spec = f"spec_{name}"
        if name not in stat_fields:
            miss(name, line, "has no matching IOStats field")
        if spec not in stat_fields:
            miss(name, line, f"has no speculative mirror IOStats.{spec}")
        if name not in writes:
            miss(name, line, "is never written by a recording method")
        for k in (name, spec):
            if k not in snap_keys:
                miss(name, line, f"is missing from snapshot() (key {k!r})")
            if k not in reset_targets:
                miss(name, line, f"is not zeroed by reset() (field {k!r})")
        if not commit_generic and commit is not None:
            merged = _self_write_targets(commit)
            if name not in merged or spec not in merged:
                miss(name, line, "is not merged by commit()")
    if commit is None:
        findings.append(Finding(
            check="iostats-pairing", file=stats.file, line=stats.line,
            symbol="IOStats.commit",
            message="IOStats has no commit() merging PendingIO buffers",
        ))
    # spec_* fields with no primary counterpart are stale leftovers
    counter_names = {n for n, _ in counters}
    for n, line in _class_fields(stats):
        if n.startswith("spec_") and n[5:] not in counter_names:
            findings.append(Finding(
                check="iostats-pairing", file=stats.file, line=line,
                symbol=f"IOStats.{n}",
                message=f"speculative counter {n!r} has no PendingIO primary",
            ))
    return findings


# --------------------------------------------------------------------------
# dataspec classification
# --------------------------------------------------------------------------

def _module_frozenset(tree: ast.Module, name: str) -> Optional[tuple[set[str], int]]:
    for stmt in tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = [t.id for t in stmt.targets if isinstance(t, ast.Name)]
            value = stmt.value
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            targets, value = [stmt.target.id], stmt.value
        else:
            continue
        if name not in targets or value is None:
            continue
        names: set[str] = set()
        for node in ast.walk(value):
            if isinstance(node, ast.Constant) and isinstance(node.value, str):
                names.add(node.value)
        return names, stmt.lineno
    return None


def check_dataspec(model: SourceModel) -> list[Finding]:
    spec = _find_class(model, "DataSpec")
    if spec is None:
        return []
    findings: list[Finding] = []
    mod = model.modules[spec.file]

    def bad(line: int, symbol: str, msg: str) -> None:
        findings.append(Finding(
            check="dataspec-classification", file=spec.file, line=line,
            symbol=symbol, message=msg,
        ))

    fields = _class_fields(spec)
    fp = _module_frozenset(mod.tree, "FINGERPRINT_FIELDS")
    cf = _module_frozenset(mod.tree, "CONTENT_FREE_FIELDS")
    if fp is None or cf is None:
        missing = [n for n, v in
                   (("FINGERPRINT_FIELDS", fp), ("CONTENT_FREE_FIELDS", cf))
                   if v is None]
        bad(spec.line, "DataSpec",
            f"module-level {' and '.join(missing)} classification set(s) "
            "not found next to DataSpec")
        return findings
    fp_names, fp_line = fp
    cf_names, cf_line = cf
    field_names = {n for n, _ in fields}
    for name, line in fields:
        in_fp, in_cf = name in fp_names, name in cf_names
        if in_fp and in_cf:
            bad(line, f"DataSpec.{name}",
                f"field {name!r} is in BOTH FINGERPRINT_FIELDS and "
                "CONTENT_FREE_FIELDS")
        elif not in_fp and not in_cf:
            bad(line, f"DataSpec.{name}",
                f"field {name!r} is unclassified: add it to "
                "FINGERPRINT_FIELDS (changes delivered bytes) or "
                "CONTENT_FREE_FIELDS (explicitly content-free)")
    for name in sorted((fp_names | cf_names) - field_names):
        which = "FINGERPRINT_FIELDS" if name in fp_names else "CONTENT_FREE_FIELDS"
        bad(fp_line if name in fp_names else cf_line, f"DataSpec.{name}",
            f"{which} lists {name!r}, which is not a DataSpec field")
    fpm = spec.methods.get("fingerprint")
    if fpm is None:
        bad(spec.line, "DataSpec.fingerprint", "DataSpec has no fingerprint()")
    else:
        uses = any(
            isinstance(n, ast.Name) and n.id == "CONTENT_FREE_FIELDS"
            for n in ast.walk(fpm)
        )
        if not uses:
            bad(fpm.lineno, "DataSpec.fingerprint",
                "fingerprint() does not consume CONTENT_FREE_FIELDS — the "
                "classification sets and the fingerprint can drift apart")
    return findings


# --------------------------------------------------------------------------
# adapter protocol
# --------------------------------------------------------------------------

def _registered_adapter_classes(model: SourceModel) -> list[tuple[ClassInfo, str, int]]:
    """(adapter class, scheme, opener line) for every @register_backend."""
    out = []
    for mod in model.modules.values():
        for stmt in mod.tree.body:
            if not isinstance(stmt, ast.FunctionDef):
                continue
            scheme = None
            for dec in stmt.decorator_list:
                if (
                    isinstance(dec, ast.Call)
                    and isinstance(dec.func, ast.Name)
                    and dec.func.id == "register_backend"
                    and dec.args
                    and isinstance(dec.args[0], ast.Constant)
                ):
                    scheme = dec.args[0].value
            if scheme is None:
                continue
            ret = stmt.returns
            cname = None
            if isinstance(ret, ast.Name):
                cname = ret.id
            elif isinstance(ret, ast.Attribute):
                cname = ret.attr
            elif isinstance(ret, ast.Constant) and isinstance(ret.value, str):
                cname = ret.value.split(".")[-1]
            cls = model.resolve_class(cname) if cname else None
            if cls is None:
                out.append((None, scheme, stmt.lineno, mod.file, stmt.name))
            else:
                out.append((cls, scheme, stmt.lineno, mod.file, stmt.name))
    return out


def _concrete_in_mro(model: SourceModel, cls: ClassInfo, mname: str) -> bool:
    for c in model.mro(cls):
        fn = c.methods.get(mname)
        if fn is not None:
            return not _is_abstract_body(fn)
    return False


def _is_wrapper(cls: ClassInfo) -> bool:
    return "inner" in cls.attr_types or any(
        isinstance(n, ast.Attribute)
        and n.attr == "inner"
        and isinstance(n.value, ast.Name)
        and n.value.id == "self"
        for fn in cls.methods.values()
        for n in ast.walk(fn)
    )


def check_adapters(model: SourceModel) -> list[Finding]:
    findings: list[Finding] = []
    for entry in _registered_adapter_classes(model):
        cls, scheme, line, file, opener = entry
        if cls is None:
            findings.append(Finding(
                check="adapter-protocol", file=file, line=line,
                symbol=f"register_backend:{scheme}",
                message=(
                    f"opener {opener!r} for scheme {scheme!r} has no "
                    "resolvable adapter-class return annotation"
                ),
            ))
            continue
        for mname in ADAPTER_REQUIRED:
            if not _concrete_in_mro(model, cls, mname):
                findings.append(Finding(
                    check="adapter-protocol", file=cls.file, line=cls.line,
                    symbol=f"{cls.name}.{mname}",
                    message=(
                        f"registered adapter {cls.name} (scheme {scheme!r}) "
                        f"does not concretely implement {mname}()"
                    ),
                ))
        if _is_wrapper(cls):
            for mname in WRAPPER_REQUIRED:
                own = any(mname in c.methods and not _is_abstract_body(c.methods[mname])
                          for c in model.mro(cls)
                          if c.name not in ("StorageAdapter", "Collection"))
                if not own:
                    findings.append(Finding(
                        check="adapter-protocol", file=cls.file, line=cls.line,
                        symbol=f"{cls.name}.{mname}",
                        message=(
                            f"wrapper adapter {cls.name} holds self.inner but "
                            f"does not forward {mname}() — the StorageAdapter "
                            "default would silently drop it"
                        ),
                    ))
    return findings
