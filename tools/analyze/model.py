"""The shared AST source model every check family walks.

Parses a source tree once into :class:`SourceModel`: per-module ASTs, the
``# guarded-by:`` / suppression comment maps, per-class lock-creation sites
(``self.X = threading.Lock()``), guarded-attribute declarations, a light
attribute-type table (``self.cache = BlockCache(...)`` => ``BlockCache``),
and method tables with base-class links.

Annotation grammar (documented in ``docs/analysis.md``):

- ``# guarded-by: <lock>`` on the line that first assigns an attribute —
  every later ``self.<attr>`` access in the owning class must sit inside
  ``with self.<lock>:``.  ``<lock>`` must be a ``threading.Lock`` /
  ``RLock`` / ``Semaphore`` attribute of the same class, or the reserved
  word ``external`` (the field IS shared mutable state, but serialization
  is external to the class — a caller-held lock, or a documented
  single-writer protocol — so in-class access checking is off).
- ``# unlocked-ok: <reason>`` on (or immediately above) an access line —
  suppresses the unlocked-access check there (double-checked fast paths,
  documented stale-tolerant reads).
- ``# blocking-ok: <reason>`` — same, for the blocking-under-lock check.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Optional

EXTERNAL = "external"  # reserved guard name: externally-serialized field

GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")
UNLOCKED_OK_RE = re.compile(r"#\s*unlocked-ok:\s*\S")
BLOCKING_OK_RE = re.compile(r"#\s*blocking-ok:\s*\S")

#: threading factory name -> lock kind.  Conditions are excluded on purpose:
#: a Condition wraps a lock the wait/notify protocol owns; modeling it as a
#: plain mutex would mispredict the witness (wait() releases while blocked).
LOCK_FACTORIES = {
    "Lock": "lock",
    "RLock": "rlock",
    "Semaphore": "semaphore",
    "BoundedSemaphore": "semaphore",
}


@dataclasses.dataclass
class LockSite:
    cls: str
    attr: str
    kind: str  # lock | rlock | semaphore
    file: str  # path as given to parse_tree
    line: int  # line of the threading.<Factory>() call

    @property
    def is_exclusive(self) -> bool:
        return self.kind in ("lock", "rlock")


@dataclasses.dataclass
class ClassInfo:
    name: str
    module: str  # dotted module path, e.g. "repro.data.backend"
    file: str
    line: int
    bases: list[str]
    node: ast.ClassDef
    locks: dict[str, LockSite] = dataclasses.field(default_factory=dict)
    #: attr -> (guard name, declaration line)
    guarded: dict[str, tuple[str, int]] = dataclasses.field(default_factory=dict)
    #: attr -> bare class name of the assigned value (best effort)
    attr_types: dict[str, str] = dataclasses.field(default_factory=dict)
    methods: dict[str, ast.FunctionDef] = dataclasses.field(default_factory=dict)

    def lock_id(self, attr: str) -> str:
        return f"{self.module}.{self.name}.{attr}"


@dataclasses.dataclass
class ModuleInfo:
    file: str
    module: str
    tree: ast.Module
    lines: list[str]
    guard_comments: dict[int, str] = dataclasses.field(default_factory=dict)
    unlocked_ok: set[int] = dataclasses.field(default_factory=set)
    blocking_ok: set[int] = dataclasses.field(default_factory=set)
    classes: list[ClassInfo] = dataclasses.field(default_factory=list)


class SourceModel:
    """All modules under one source root, cross-linked by class name."""

    def __init__(self) -> None:
        self.modules: dict[str, ModuleInfo] = {}  # file -> info
        self._by_name: dict[str, list[ClassInfo]] = {}

    # ------------------------------------------------------------- lookup
    def classes(self) -> list[ClassInfo]:
        return [c for m in self.modules.values() for c in m.classes]

    def resolve_class(self, name: str) -> Optional[ClassInfo]:
        """The unique class of that bare name, or None (unknown/ambiguous)."""
        hits = self._by_name.get(name, [])
        return hits[0] if len(hits) == 1 else None

    def mro(self, cls: ClassInfo) -> list[ClassInfo]:
        """cls plus its in-model ancestors, nearest first (linearized by
        simple DFS — good enough for single-inheritance repo code)."""
        out, seen, stack = [], set(), [cls]
        while stack:
            c = stack.pop(0)
            if c.name in seen:
                continue
            seen.add(c.name)
            out.append(c)
            for b in c.bases:
                bc = self.resolve_class(b)
                if bc is not None:
                    stack.append(bc)
        return out

    def subclasses(self, cls: ClassInfo) -> list[ClassInfo]:
        out = []
        for c in self.classes():
            if c is cls:
                continue
            if any(m.name == cls.name for m in self.mro(c)):
                out.append(c)
        return out

    def find_method(self, cls: ClassInfo, name: str) -> Optional[tuple[ClassInfo, ast.FunctionDef]]:
        for c in self.mro(cls):
            fn = c.methods.get(name)
            if fn is not None:
                return c, fn
        return None

    # ------------------------------------------------------------- build
    def add_module(self, info: ModuleInfo) -> None:
        self.modules[info.file] = info
        for c in info.classes:
            self._by_name.setdefault(c.name, []).append(c)


def _comment_maps(lines: list[str]) -> tuple[dict[int, str], set[int], set[int]]:
    guards: dict[int, str] = {}
    unlocked: set[int] = set()
    blocking: set[int] = set()
    for i, text in enumerate(lines, start=1):
        m = GUARDED_RE.search(text)
        if m:
            guards[i] = m.group(1)
        if UNLOCKED_OK_RE.search(text):
            unlocked.add(i)
        if BLOCKING_OK_RE.search(text):
            blocking.add(i)
    return guards, unlocked, blocking


def _lock_kind_of(value: ast.AST) -> Optional[str]:
    """``threading.Lock()`` / bare ``Lock()`` call -> kind, else None."""
    if not isinstance(value, ast.Call):
        return None
    fn = value.func
    if isinstance(fn, ast.Attribute):
        name = fn.attr
    elif isinstance(fn, ast.Name):
        name = fn.id
    else:
        return None
    return LOCK_FACTORIES.get(name)


def _value_class_names(value: ast.AST) -> list[str]:
    """Bare names of classes plausibly constructed in ``value`` — the first
    resolvable one becomes the attribute's inferred type.  Handles direct
    calls, ``X(...) if cond else None``, ``arg or X(...)`` and plain
    ``self.x = param`` (the caller resolves params via annotations)."""
    out: list[str] = []
    for node in ast.walk(value):
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name):
                out.append(fn.id)
            elif isinstance(fn, ast.Attribute):
                out.append(fn.attr)
    return out


def _annotation_class_name(ann: ast.AST) -> Optional[str]:
    """Innermost plausible class name of an annotation: ``X`` -> X,
    ``Optional[X]`` -> X, ``"X"`` -> X."""
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value.split("[")[-1].rstrip("]").split(".")[-1] or None
    if isinstance(ann, ast.Subscript):
        return _annotation_class_name(ann.slice)
    if isinstance(ann, ast.Attribute):
        return ann.attr
    return None


def _guard_for_stmt(stmt: ast.stmt, guards: dict[int, str]) -> Optional[tuple[str, int]]:
    """guarded-by annotation on any line the statement spans."""
    for ln in range(stmt.lineno, (stmt.end_lineno or stmt.lineno) + 1):
        g = guards.get(ln)
        if g is not None:
            return g, stmt.lineno
    return None


def _scan_class(cls: ast.ClassDef, module: str, file: str,
                guards: dict[int, str]) -> ClassInfo:
    info = ClassInfo(
        name=cls.name,
        module=module,
        file=file,
        line=cls.lineno,
        bases=[b.id if isinstance(b, ast.Name) else getattr(b, "attr", "")
               for b in cls.bases],
        node=cls,
    )
    # class-level fields (dataclass style): AnnAssign / Assign targets
    for stmt in cls.body:
        if isinstance(stmt, ast.FunctionDef):
            info.methods[stmt.name] = stmt
            continue
        targets: list[str] = []
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            targets = [stmt.target.id]
        elif isinstance(stmt, ast.Assign):
            targets = [t.id for t in stmt.targets if isinstance(t, ast.Name)]
        if not targets:
            continue
        g = _guard_for_stmt(stmt, guards)
        if g is not None:
            for t in targets:
                info.guarded[t] = g

    # instance attributes: every `self.X = ...` anywhere in the class body
    param_types: dict[str, dict[str, str]] = {}
    for mname, fn in info.methods.items():
        ptypes: dict[str, str] = {}
        for arg in list(fn.args.args) + list(fn.args.kwonlyargs):
            if arg.annotation is not None:
                cn = _annotation_class_name(arg.annotation)
                if cn:
                    ptypes[arg.arg] = cn
        param_types[mname] = ptypes

    for mname, fn in info.methods.items():
        for stmt in ast.walk(fn):
            target: Optional[ast.Attribute] = None
            value: Optional[ast.AST] = None
            ann: Optional[ast.AST] = None
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                    isinstance(stmt.targets[0], ast.Attribute):
                target, value = stmt.targets[0], stmt.value
            elif isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Attribute):
                target, value, ann = stmt.target, stmt.value, stmt.annotation
            if target is None or not (
                isinstance(target.value, ast.Name) and target.value.id == "self"
            ):
                continue
            attr = target.attr
            kind = _lock_kind_of(value) if value is not None else None
            if kind is not None and attr not in info.locks:
                info.locks[attr] = LockSite(
                    cls=cls.name, attr=attr, kind=kind, file=file,
                    line=value.lineno,
                )
            g = _guard_for_stmt(stmt, guards)
            if g is not None and attr not in info.guarded:
                info.guarded[attr] = g
            if attr not in info.attr_types:
                cand: list[str] = []
                if value is not None:
                    cand.extend(_value_class_names(value))
                    if isinstance(value, ast.Name):
                        cand.append(param_types.get(mname, {}).get(value.id, ""))
                    for sub in ast.walk(value):
                        if isinstance(sub, ast.Name):
                            pt = param_types.get(mname, {}).get(sub.id)
                            if pt:
                                cand.append(pt)
                if ann is not None:
                    cn = _annotation_class_name(ann)
                    if cn:
                        cand.append(cn)
                for cn in cand:
                    if cn:
                        info.attr_types[attr] = cn
                        break
    return info


def module_name_for(path: str, src_root: str) -> str:
    rel = os.path.relpath(path, src_root)
    return rel[:-3].replace(os.sep, ".") if rel.endswith(".py") else rel


def parse_file(path: str, src_root: str) -> ModuleInfo:
    with open(path) as f:
        text = f.read()
    lines = text.splitlines()
    tree = ast.parse(text, filename=path)
    guards, unlocked, blocking = _comment_maps(lines)
    module = module_name_for(path, src_root)
    info = ModuleInfo(
        file=path, module=module, tree=tree, lines=lines,
        guard_comments=guards, unlocked_ok=unlocked, blocking_ok=blocking,
    )
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            info.classes.append(_scan_class(node, module, path, guards))
    return info


def build_model(src_root: str) -> SourceModel:
    model = SourceModel()
    for dirpath, dirnames, filenames in os.walk(src_root):
        dirnames[:] = sorted(
            d for d in dirnames
            if d not in ("__pycache__", ".git", ".pytest_cache")
        )
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                model.add_module(parse_file(os.path.join(dirpath, fn), src_root))
    return model
