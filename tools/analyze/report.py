"""Findings and the zero-findings-forward baseline.

A :class:`Finding` is one violation at one ``file:line``.  The baseline file
(``tools/analyze/baseline.json``) holds findings that predate the gate and
are *accepted* — entries match on ``(check, file, symbol)`` (NOT line, so
unrelated edits above a baselined finding do not churn the file).  The gate
fails on any finding not covered by the baseline, and warns on stale
baseline entries so the file shrinks monotonically toward the empty list.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    check: str  # check id, e.g. "unlocked-access"
    file: str  # path as analyzed (relative to the --src root's parent)
    line: int
    symbol: str  # "Class.attr" / "Class.method" / "module" — baseline key
    message: str

    def render(self) -> str:
        return f"{self.file}:{self.line}: [{self.check}] {self.message}"

    def key(self) -> tuple:
        return (self.check, self.file, self.symbol)


def load_baseline(path: Optional[str]) -> list[dict]:
    if path is None or not os.path.exists(path):
        return []
    with open(path) as f:
        entries = json.load(f)
    if not isinstance(entries, list):
        raise ValueError(f"baseline {path} must be a JSON list of findings")
    return entries


def apply_baseline(
    findings: list[Finding], entries: list[dict]
) -> tuple[list[Finding], list[dict]]:
    """(unbaselined findings, stale baseline entries)."""
    keys = {
        (e.get("check"), e.get("file"), e.get("symbol")) for e in entries
    }
    fresh = [f for f in findings if f.key() not in keys]
    found_keys = {f.key() for f in findings}
    stale = [
        e
        for e in entries
        if (e.get("check"), e.get("file"), e.get("symbol")) not in found_keys
    ]
    return fresh, stale


def baseline_entry(f: Finding) -> dict:
    """The JSON form to paste into baseline.json to accept ``f``."""
    return {
        "check": f.check,
        "file": f.file,
        "symbol": f.symbol,
        "reason": "TODO: justify why this finding is accepted",
    }
