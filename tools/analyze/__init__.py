"""Static concurrency & contract analyzer (CI gate).

Run from the repo root::

    python tools/analyze --src src --baseline tools/analyze/baseline.json

Check families (ids used in findings and the baseline):

- ``unlocked-access`` / ``blocking-under-lock`` / ``bad-annotation`` —
  lock discipline over ``# guarded-by:`` annotations (:mod:`.locks`);
- ``lock-order-cycle`` — cycles in the static lock-acquisition graph
  (:mod:`.lockorder`), cross-checked at runtime by :mod:`.runtime`;
- ``iostats-pairing`` / ``dataspec-classification`` / ``adapter-protocol``
  — API contracts (:mod:`.contracts`).

See ``docs/analysis.md`` for the annotation grammar and workflow.
"""
from __future__ import annotations

from .contracts import check_adapters, check_dataspec, check_iostats
from .lockorder import check_lock_order, static_lock_graph
from .locks import check_locks
from .model import SourceModel, build_model
from .report import Finding, apply_baseline, baseline_entry, load_baseline

__all__ = [
    "Finding",
    "SourceModel",
    "apply_baseline",
    "baseline_entry",
    "build_model",
    "check_adapters",
    "check_dataspec",
    "check_iostats",
    "check_lock_order",
    "check_locks",
    "load_baseline",
    "run_all",
    "static_lock_graph",
]

CHECKS = (
    check_locks,
    check_lock_order,
    check_iostats,
    check_dataspec,
    check_adapters,
)


def run_all(src_root: str, model: SourceModel | None = None) -> list[Finding]:
    """Every finding from every check family, sorted by location."""
    if model is None:
        model = build_model(src_root)
    findings: list[Finding] = []
    for check in CHECKS:
        findings.extend(check(model))
    findings.sort(key=lambda f: (f.file, f.line, f.check, f.symbol))
    return findings
